"""In-kernel paged attention + copy-on-write prefix sharing.

Three layers of evidence, cheapest first:

* kernel vs oracle — ``kernels.paged_attention`` (interpret=True) against
  the dense ``kernels.ref.paged_attention_ref`` across page sizes,
  GQA/MQA, windows, softcap, the MLA two-component form, fragmented and
  permuted page tables, ragged/padded query batches, and full-pool
  occupancy (allclose: same math, different reduction order);
* lm-level bit equality — ``paged_prefill``/``paged_decode_step`` with
  ``kernel="pallas"`` produce the SAME greedy tokens as the
  ``kernel="gather"`` dense-materialize baseline on bounded decode
  horizons (the two paths differ by 1 bf16 ulp in logits, so horizons
  are kept where argmax is stable — see EXPERIMENTS.md fig_serve_kernel);
* COW/refcount — ``PagePool`` share/cow/release invariants, and the
  serving-level guarantees: a pinned prefix is never corrupted by a
  sharer's divergent writes, preempting a sharing slot leaks nothing,
  and ``PagePool.check()`` stays clean through preemption-heavy runs.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.models.lm import (lm_init, paged_cache_init, paged_decode_step,
                             paged_prefill)
from repro.serve.engine import PagedServeEngine, Request
from repro.serve.kv_pool import PagePool


def _mk(seed, B=2, S=1, H=4, K=2, Dk=16, Dv=16, P=12, ps=4, npps=4,
        filled=None, permute=True, q2dim=None):
    """Random paged-attention problem with a fragmented, permuted pool."""
    r = np.random.default_rng(seed)
    k = r.standard_normal((P, ps, K, Dk), np.float32)
    v = r.standard_normal((P, ps, K, Dv), np.float32)
    order = r.permutation(P) if permute else np.arange(P)
    tables = np.full((B, npps), -1, np.int32)
    kpos = np.full((P, ps), -1, np.int32)
    filled = [npps] * B if filled is None else filled
    n = 0
    for b in range(B):
        for j in range(filled[b]):
            pg = order[n]; n += 1
            tables[b, j] = pg
            kpos[pg] = j * ps + np.arange(ps)
    hist = np.asarray([f * ps for f in filled])
    q_pos = hist[:, None] - 1 + np.arange(S)[None]      # last S positions
    q = r.standard_normal((B, S, H, Dk), np.float32)
    q2 = k2 = None
    if q2dim:
        q2 = r.standard_normal((B, S, H, q2dim), np.float32)
        k2 = r.standard_normal((P, ps, K, q2dim), np.float32)
    to = jnp.asarray
    return (to(q), to(k), to(v), to(kpos, jnp.int32), to(tables, jnp.int32),
            to(q_pos, jnp.int32), (to(q2) if q2 is not None else None),
            (to(k2) if k2 is not None else None))


def _both(args, **kw):
    q, k, v, kpos, tables, q_pos, q2, k2 = args
    out = paged_attention(q, k, v, kpos, tables, q_pos, q2=q2, k2=k2,
                          interpret=True, block_q=8, **kw)
    ref = paged_attention_ref(q, k, v, kpos, tables, q_pos, q2=q2, k2=k2,
                              **kw)
    return out, ref


# ---------------------------------------------------------------------------
# Kernel vs dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ps,npps", [(2, 6), (4, 4), (8, 2)])
def test_kernel_matches_ref_across_page_sizes(ps, npps):
    out, ref = _both(_mk(0, P=16, ps=ps, npps=npps))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (4, 1)])
def test_kernel_matches_ref_mha_gqa_mqa(H, K):
    out, ref = _both(_mk(1, H=H, K=K))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_matches_ref_window_and_softcap():
    out, ref = _both(_mk(2), window=6, softcap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_matches_ref_mla_two_component():
    # absorbed MLA: scores = q_abs . ckv + q_rope . k_rope, shared V = ckv
    out, ref = _both(_mk(3, K=1, H=4, q2dim=8),
                     scale=1.0 / math.sqrt(16 + 8))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_kernel_matches_ref_prefill_ragged_and_padded():
    # S=8 prefill rows with per-row histories; queries past the pad line
    # carry q_pos=-1 and must come back all-zero
    args = list(_mk(4, B=3, S=8, filled=[4, 2, 3]))
    q_pos = np.array(args[5])
    q_pos[1, 5:] = -1                                   # row 1: 5 real rows
    args[5] = jnp.asarray(q_pos)
    out, ref = _both(tuple(args))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert np.all(np.asarray(out)[1, 5:] == 0.0)


def test_kernel_matches_ref_partial_tables_full_pool():
    # every pool page allocated (full occupancy), slots with ragged page
    # counts including an EMPTY slot (all-dead table)
    out, ref = _both(_mk(5, B=4, P=12, npps=4, filled=[4, 0, 3, 4]))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
    assert np.all(np.asarray(out)[1] == 0.0)            # dead slot -> zeros


# ---------------------------------------------------------------------------
# lm-level: pallas vs gather greedy-token equality
# ---------------------------------------------------------------------------

def _greedy(cfg, params, kernel, seed, steps=4, slots=3, ps=4, npps=8,
            P=None):
    """Prefill + greedy decode over a fragmented paged pool; returns the
    (steps+1, slots) token matrix plus the matching top-2 logit gap at
    every emitted token (argmax stability margin, in f32)."""
    P = P if P is not None else slots * npps
    r = np.random.default_rng(seed)
    perm = r.permutation(P)
    tables = np.full((slots, npps), -1, np.int32)
    n = 0
    for b in range(slots):
        tables[b, :npps - 1] = perm[n:n + npps - 1]
        n += npps - 1
    tables = jnp.asarray(tables)
    S = 8
    toks = jnp.asarray(r.integers(1, cfg.vocab, (slots, S)), jnp.int32)
    lens = jnp.asarray(r.integers(2, S + 1, (slots,)), jnp.int32)
    sids = jnp.arange(slots, dtype=jnp.int32)
    pool = paged_cache_init(cfg, slots, P, ps)
    lg, pool = paged_prefill(params, pool, tables, toks, lens, sids, cfg,
                             kernel=kernel)
    def _gap(row_logits):                               # (slots, vocab)
        top2 = jax.lax.top_k(row_logits.astype(jnp.float32), 2)[0]
        return np.asarray(top2[:, 0] - top2[:, 1])

    seq = [np.asarray(jnp.argmax(lg[:, 0], -1))]
    gaps = [_gap(lg[:, 0])]
    pos = lens[:, None].astype(jnp.int32)
    t = jnp.argmax(lg[:, 0], -1)[:, None].astype(jnp.int32)
    for _ in range(steps):
        lg, pool = paged_decode_step(params, pool, tables, t, pos, cfg,
                                     kernel=kernel)
        t = jnp.argmax(lg, -1).astype(jnp.int32)
        seq.append(np.asarray(t[:, 0]))
        gaps.append(_gap(lg[:, 0]))
        pos = pos + 1
    return np.stack(seq), np.stack(gaps)


# the two kernels reduce the softmax in different orders, so logits agree
# only to ~1 bf16 ulp (~8e-3 at unit scale); a greedy argmax sitting on a
# near-tie may legitimately flip.  Equality contract: token streams match
# exactly until a slot hits a near-tie (top-2 gap below a few ulps); past
# that flip the slot's histories differ and tokens are unconstrained.
_ULP_TIE = 0.05


def _assert_tokens_match_modulo_ties(a, ga, b, ctx):
    assert a.shape == b.shape, ctx
    for s in range(a.shape[1]):                         # slots independent
        col = np.nonzero(a[:, s] != b[:, s])[0]
        if col.size:
            first = col[0]
            assert ga[first, s] < _ULP_TIE, (
                ctx, s, first, float(ga[first, s]),
                a[:, s].tolist(), b[:, s].tolist())


@pytest.mark.parametrize("arch", ["qwen3-14b", "deepseek-v2-236b"])
def test_lm_tokens_pallas_equals_gather(arch):
    cfg = get_config(arch, smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    for seed in (1, 2):
        a, ga = _greedy(cfg, params, "gather", seed)
        b, _ = _greedy(cfg, params, "pallas", seed)
        _assert_tokens_match_modulo_ties(a, ga, b, (arch, seed))


def test_lm_tokens_equal_at_full_occupancy():
    # every pool page owned by some slot: the kernel sees zero dead pages
    cfg = get_config("qwen3-14b", smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    kw = dict(slots=2, ps=2, npps=6, P=10)              # 2*(6-1) pages used
    a, ga = _greedy(cfg, params, "gather", 7, **kw)
    b, _ = _greedy(cfg, params, "pallas", 7, **kw)
    _assert_tokens_match_modulo_ties(a, ga, b, "full-occupancy")


# ---------------------------------------------------------------------------
# PagePool refcounts + COW (host-side invariants)
# ---------------------------------------------------------------------------

def test_pool_refcount_share_cow_release():
    pool = PagePool(n_pages=16, page_size=4, slots=4, pages_per_slot=6)
    assert pool.alloc(0, 3) is not None
    pages = pool.pages_of(0)
    assert pool.register_prefix(b"k", list(range(8)), pages[:2])
    pool.check()
    # registered pages are pinned and no longer writable by their owner
    assert not pool.writable(0, pages[0]) and pool.writable(0, pages[2])
    e = pool.lookup_prefix(b"k", list(range(9)))
    assert e is not None and e["pages"] == pages[:2]
    assert pool.lookup_prefix(b"k", [0, 1, 99]) is None  # token-verified
    assert pool.share(1, e["pages"]) and pool.alloc(1, 1) is not None
    pool.check()
    assert int(pool.refcount[pages[0]]) == 3             # slot0+slot1+registry
    # COW: slot 1 breaks the boundary page out; the original stays shared
    src, dst = pool.cow_page(1, 1)
    assert src == pages[1] and pool.writable(1, dst)
    pool.check()
    # releases free only refcount-zero pages
    assert pool.free_slot(0) == [pages[2]]
    freed = pool.free_slot(1)
    assert pages[0] not in freed and dst in freed
    pool.check()
    assert int(pool.refcount[pages[0]]) == 1             # registry pin only
    assert set(pool.drop_prefix(b"k")) == set(pages[:2])
    pool.check()
    assert pool.free_pages == pool.n_pages


def test_pool_prefix_eviction_lru():
    pool = PagePool(n_pages=8, page_size=4, slots=4, pages_per_slot=4)
    for s, key in enumerate([b"a", b"b"]):
        pool.alloc(s, 2)
        pool.register_prefix(key, [s] * 8, pool.pages_of(s))
        pool.free_slot(s)
    pool.check()
    assert pool.free_pages == 4
    pool.lookup_prefix(b"a", [0] * 8)                    # touch a: b is LRU
    pool.evict_prefixes(6)
    assert pool.prefix_keys() == [b"a"]
    pool.check()
    pool.evict_prefixes(pool.n_pages)
    assert pool.free_pages == pool.n_pages
    pool.check()


def test_pool_check_catches_refcount_leak():
    pool = PagePool(n_pages=8, page_size=4, slots=2, pages_per_slot=4)
    pool.alloc(0, 2)
    pool.refcount[pool.pages_of(0)[0]] += 1              # corrupt on purpose
    with pytest.raises(AssertionError):
        pool.check()


# ---------------------------------------------------------------------------
# Serving-level COW: the pinned prefix survives its sharers
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen3-14b", smoke=True)
    return cfg, lm_init(jax.random.PRNGKey(0), cfg)


def test_warm_divergence_does_not_corrupt_prefix(qwen):
    """Mid-page divergence: the sharer COWs the boundary page and writes
    its suffix into the copy; replaying the ORIGINAL prompt afterwards
    still yields the original continuation."""
    cfg, params = qwen
    r = np.random.default_rng(3)
    p1 = r.integers(1, cfg.vocab, 18)                    # boundary mid-page
    eng = PagedServeEngine(cfg, params, slots=4, page_size=4,
                           pages_per_slot=8, pool_pages=28, kernel="gather",
                           prefix_sharing=True)
    a = Request(rid=0, prompt=p1.copy(), max_new=4)
    eng.run([a])
    eng.pool.check()
    assert eng.stats["prefix_registered"] == 1
    # sharer diverges inside the boundary page
    p2 = np.concatenate([p1, r.integers(1, cfg.vocab, 5)])
    b = Request(rid=1, prompt=p2, max_new=4)
    eng.run([b])
    eng.pool.check()
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["cow_pages"] >= 1
    # replay the original prompt: warm again, same tokens as the cold run
    c = Request(rid=2, prompt=p1.copy(), max_new=4)
    eng.run([c])
    eng.pool.check()
    assert eng.stats["prefix_hits"] == 2
    assert c.out == a.out, (a.out, c.out)


def test_warm_prefill_writes_only_suffix(qwen):
    cfg, params = qwen
    r = np.random.default_rng(4)
    p1 = r.integers(1, cfg.vocab, 16)                    # page-aligned
    eng = PagedServeEngine(cfg, params, slots=4, page_size=4,
                           pages_per_slot=8, pool_pages=28,
                           prefix_sharing=True)
    eng.run([Request(rid=0, prompt=p1.copy(), max_new=2)])
    cold_rows = eng.stats["prefill_rows"]
    assert cold_rows == 16
    p2 = np.concatenate([p1, r.integers(1, cfg.vocab, 6)])
    eng.run([Request(rid=1, prompt=p2, max_new=2)])
    assert eng.stats["prefill_rows"] - cold_rows == 6    # suffix only
    assert eng.stats["prefix_hits"] == 1
    eng.pool.check()


def test_preempted_sharer_leaks_nothing(qwen):
    """A batch-class sharer preempted mid-decode releases its references;
    the pinned prefix stays intact for its next (re)admission and
    ``check()`` stays clean throughout."""
    cfg, params = qwen
    r = np.random.default_rng(5)
    p1 = r.integers(1, cfg.vocab, 16)
    eng = PagedServeEngine(cfg, params, slots=2, page_size=4,
                           pages_per_slot=8, pool_pages=12,
                           prefix_sharing=True)
    eng.run([Request(rid=0, prompt=p1.copy(), max_new=2)])
    eng.pool.check()
    # sharer (batch class) + an interactive flood that preempts it
    sharer = Request(rid=1, prompt=np.concatenate(
        [p1, r.integers(1, cfg.vocab, 4)]), max_new=24, priority="batch")
    flood = [Request(rid=2 + i, prompt=r.integers(1, cfg.vocab, 8),
                     max_new=16) for i in range(3)]
    eng.run([sharer] + flood)
    eng.pool.check()                                     # zero leaks
    assert sharer.done
    # all references released: only registry pins remain
    held = int(np.sum(eng.pool.refcount > 0))
    pinned = sum(len(eng.pool._prefix[k]["pages"])
                 for k in eng.pool.prefix_keys())
    assert held == pinned


def test_preemption_heavy_mixed_run_stays_clean(qwen):
    """Oversubscribed pool + shared prefixes + preemption churn: every
    request completes and the allocator invariants hold at the end."""
    cfg, params = qwen
    r = np.random.default_rng(6)
    base = r.integers(1, cfg.vocab, 12)
    reqs = []
    for i in range(8):
        if i % 2 == 0:
            prompt = np.concatenate([base, r.integers(1, cfg.vocab, 1 + i)])
        else:
            prompt = r.integers(1, cfg.vocab, 8 + i)
        reqs.append(Request(rid=i, prompt=prompt, max_new=6,
                            priority="batch" if i % 3 == 0 else "interactive"))
    eng = PagedServeEngine(cfg, params, slots=3, page_size=4,
                           pages_per_slot=8, pool_pages=14,
                           prefix_sharing=True)
    stats = eng.run(reqs)
    eng.pool.check()
    assert stats["decoded"] > 0
    done = [q for q in reqs if q.done]
    assert len(done) == len(reqs)


def test_engine_tokens_identical_dense_gather_pallas(qwen):
    """One short trace through all three serving paths — the fixed-ring
    dense engine, the paged gather engine, and the paged in-kernel
    engine (interpret mode off-TPU) — must emit identical tokens."""
    from repro.serve.engine import ServeEngine
    cfg, params = qwen

    def trace():
        r = np.random.default_rng(9)
        return [Request(rid=i, prompt=r.integers(1, cfg.vocab, 6 + 2 * i),
                        max_new=3) for i in range(3)]

    outs = {}
    for name, mk in (
            ("dense", lambda: ServeEngine(cfg, params, slots=2,
                                          capacity=16)),
            ("gather", lambda: PagedServeEngine(cfg, params, slots=2,
                                                page_size=4,
                                                pages_per_slot=4,
                                                kernel="gather")),
            ("pallas", lambda: PagedServeEngine(cfg, params, slots=2,
                                                page_size=4,
                                                pages_per_slot=4,
                                                kernel="pallas"))):
        t = trace()
        mk().run(t, max_steps=500)
        assert all(r.done for r in t)
        outs[name] = [r.out for r in t]
    assert outs["dense"] == outs["gather"] == outs["pallas"], outs
