"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import attention_ref, ssd_ref
from repro.kernels.ssd_scan import ssd_scan


def _qkv(B, H, K, Sq, Sk, D, Dv, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, K, Sk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, K, Sk, Dv)).astype(dtype)
    return q, k, v


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,Sq,Sk,D", [
    (1, 4, 4, 128, 128, 64),        # MHA square
    (2, 8, 2, 256, 256, 64),        # GQA
    (1, 4, 1, 128, 384, 128),       # MQA, rectangular
    (1, 2, 2, 96, 160, 64),         # non-multiple of block
])
def test_flash_attention_sweep(dtype, B, H, K, Sq, Sk, D):
    q, k, v = _qkv(B, H, K, Sq, Sk, D, D, dtype)
    out = flash_attention(q, k, v, causal=True, interpret=True, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window,softcap,causal", [
    (32, None, True), (None, 25.0, True), (64, 50.0, True), (None, None, False),
])
def test_flash_attention_variants(window, softcap, causal):
    q, k, v = _qkv(1, 4, 2, 128, 128, 64, 64, jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          softcap=softcap, interpret=True, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_asymmetric_vdim():
    q, k, v = _qkv(1, 4, 4, 128, 128, 64, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, interpret=True, bq=64, bk=64)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Bz,S,H,P,N,chunk", [
    (1, 128, 2, 64, 32, 64),
    (2, 256, 4, 64, 64, 128),
    (1, 192, 2, 32, 16, 64),
])
def test_ssd_scan_sweep(dtype, Bz, S, H, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (Bz, S, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, H))).astype(dtype)
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bz, S, N)).astype(dtype)
    C = jax.random.normal(ks[4], (Bz, S, N)).astype(dtype)
    y = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, _ = ssd_ref(x, dt, A, B, C)
    tol = 3e-4 if dtype == jnp.float32 else 6e-2
    err = float(jnp.abs(y.astype(jnp.float32) - yr).max()
                / (jnp.abs(yr).max() + 1e-9))
    assert err < tol, f"ssd rel err {err}"


def test_model_path_matches_kernel():
    """The model's XLA ssd path and the Pallas kernel agree."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    Bz, S, H, P, N = 2, 128, 4, 32, 16
    x = jax.random.normal(ks[0], (Bz, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bz, S, H)))
    A = -jnp.exp(0.3 * jax.random.normal(ks[2], (H,)))
    B = jax.random.normal(ks[3], (Bz, S, N))
    C = jax.random.normal(ks[4], (Bz, S, N))
    y_model, _ = ssd_chunked(x, dt, A, B, C, 64)
    y_kernel = ssd_scan(x, dt, A, B, C, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=2e-3, rtol=2e-3)
