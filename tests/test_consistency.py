"""Decode-vs-full-forward equivalence: validates KV ring buffers, windowed
caches, MLA weight absorption, and the SSD chunked<->recurrent duality."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.lm import decode_step, lm_hidden, lm_init, lm_logits, prefill

S, B, TAIL = 24, 2, 4

DECODER_ARCHS = [a for a in ARCHS if a != "whisper-base"]


def _uncapped(cfg):
    """MoE capacity drops are data-dependent (full forward drops overflow
    tokens; 1-token decode cannot) — equivalence tests lift the cap."""
    groups = []
    for g in cfg.groups:
        pat = []
        for b in g.pattern:
            if b.moe is not None:
                b = dataclasses.replace(b, moe=dataclasses.replace(
                    b.moe, capacity_factor=float(b.moe.n_experts)))
            pat.append(b)
        groups.append(dataclasses.replace(g, pattern=tuple(pat)))
    return dataclasses.replace(cfg, groups=tuple(groups))


@pytest.mark.parametrize("arch", DECODER_ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = _uncapped(get_config(arch, smoke=True))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    inputs = {"tokens": toks}
    if cfg.frontend == "vlm_patch":
        emb = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.frontend_len, cfg.d_model),
            jnp.bfloat16)
        inputs["embeds"] = emb

    h, _, _ = lm_hidden(params, inputs, cfg)
    full = lm_logits(params, h, cfg).astype(jnp.float32)

    sp = S - TAIL
    pre_inputs = dict(inputs, tokens=toks[:, :sp])
    lg, caches = prefill(params, pre_inputs, cfg,
                         capacity=S + (cfg.frontend_len or 0))
    outs = [lg]
    dstep = jax.jit(lambda p, c, t, po: decode_step(p, c, t, po, cfg))
    off = cfg.frontend_len if cfg.frontend == "vlm_patch" else 0
    for i in range(sp, S):
        lg, caches = dstep(params, caches, toks[:, i:i + 1],
                           jnp.full((B, 1), i + off, jnp.int32))
        outs.append(lg)
    dec = jnp.concatenate(outs[:-1], axis=1).astype(jnp.float32)
    ref = full[:, sp - 1 + off:S - 1 + off]
    err = float(jnp.abs(dec - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert err < 0.05, f"{arch}: decode/full mismatch rel={err:.3e}"


def test_ssd_chunk_sizes_agree():
    """Chunked SSD must be invariant to chunk size (algebraic identity)."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(0)
    Bz, Sq, H, P, N = 2, 64, 4, 16, 8
    x = jax.random.normal(key, (Bz, Sq, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (Bz, Sq, H)))
    A = -jnp.exp(0.3 * jax.random.normal(jax.random.PRNGKey(2), (H,)))
    Bm = jax.random.normal(jax.random.PRNGKey(3), (Bz, Sq, N))
    Cm = jax.random.normal(jax.random.PRNGKey(4), (Bz, Sq, N))
    y16, h16 = ssd_chunked(x, dt, A, Bm, Cm, 16)
    y64, h64 = ssd_chunked(x, dt, A, Bm, Cm, 64)
    assert jnp.allclose(y16, y64, atol=1e-3), "chunk-size variance"
    assert jnp.allclose(h16, h64, atol=1e-3)


def test_whisper_decode_consistency():
    cfg = get_config("whisper-base", smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    from repro.models.lm import encoder_apply
    frames = 0.02 * jax.random.normal(
        jax.random.PRNGKey(1), (B, cfg.encoder.seq_len, cfg.d_model),
        jnp.bfloat16)
    enc = encoder_apply(params, frames, cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    h, _, _ = lm_hidden(params, {"tokens": toks}, cfg, enc_out=enc)
    full = lm_logits(params, h, cfg).astype(jnp.float32)
    sp = S - TAIL
    lg, caches = prefill(params, {"tokens": toks[:, :sp]}, cfg, enc_out=enc,
                         capacity=S)
    outs = [lg]
    for i in range(sp, S):
        lg, caches = decode_step(params, caches, toks[:, i:i + 1],
                                 jnp.full((B, 1), i, jnp.int32), cfg,
                                 enc_out=enc)
        outs.append(lg)
    dec = jnp.concatenate(outs[:-1], axis=1).astype(jnp.float32)
    ref = full[:, sp - 1:S - 1]
    err = float(jnp.abs(dec - ref).max() / (jnp.abs(ref).max() + 1e-9))
    assert err < 0.05, f"whisper decode mismatch rel={err:.3e}"
