"""Serving subsystem: slot admission, continuous decode, the paged KV
pool (alloc/free invariants, batched prefill, priority preemption), and
capacity guards."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import lm_init
from repro.serve.engine import PagedServeEngine, Request, ServeEngine
from repro.serve.kv_pool import PagePool
from repro.serve.scheduler import AdmissionScheduler, bucket_len


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b", smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _trace(cfg, n, lens=(8, 12, 16), max_new=6, batch_every=0, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab,
                                        size=int(lens[i % len(lens)])),
                    max_new=max_new,
                    priority=("batch" if batch_every
                              and i % batch_every == 0 else "interactive"))
            for i in range(n)]


# ---------------------------------------------------------------------------
# Fixed-partition baseline (seed behavior must survive the rework)
# ---------------------------------------------------------------------------

def test_engine_completes_requests(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, slots=4, capacity=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=6) for i in range(6)]
    stats = eng.run(reqs, max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= r.max_new for r in reqs)
    assert stats["admitted"] == 6
    assert stats["decoded"] > 0


def test_engine_batches_share_steps(setup):
    """Continuous batching: 4 concurrent requests must cost far fewer steps
    than 4 sequential ones (the array-launch property at the serving layer)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, slots=4, capacity=64)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=10) for i in range(4)]
    stats = eng.run(reqs, max_steps=200)
    assert stats["steps"] <= 15, stats   # ~10 shared steps, not 40


# ---------------------------------------------------------------------------
# PagePool allocator invariants
# ---------------------------------------------------------------------------

def test_pool_alloc_free_invariants():
    pool = PagePool(n_pages=16, page_size=8, slots=4, pages_per_slot=8)
    assert pool.alloc(0, 3) is not None
    assert pool.alloc(1, 5) is not None
    pool.check()
    assert pool.used_pages == 8 and pool.free_pages == 8
    assert pool.stats["watermark"] == 8
    assert pool.n_allocated(0) == 3 and pool.pages_of(1)[0] >= 0
    freed = pool.free_slot(0)
    assert len(freed) == 3
    pool.check()
    assert pool.used_pages == 5
    # watermark is a high-water mark, not current occupancy
    assert pool.stats["watermark"] == 8


def test_pool_exhaustion_and_fragmented_reuse():
    pool = PagePool(n_pages=8, page_size=4, slots=4, pages_per_slot=4)
    assert pool.alloc(0, 4) is not None
    assert pool.alloc(1, 4) is not None
    assert pool.alloc(2, 1) is None                 # pool empty
    assert pool.stats["alloc_failures"] == 1
    pool.free_slot(0)                               # fragmented free list
    got = pool.alloc(2, 3)
    assert got is not None and len(got) == 3
    pool.check()
    # a slot can never exceed its table width, even with free pages around
    pool.free_slot(1)
    assert pool.alloc(2, 2) is None                 # 3 + 2 > pages_per_slot
    pool.check()
    pool.reset()
    assert pool.free_pages == 8 and pool.used_pages == 0
    pool.check()


# ---------------------------------------------------------------------------
# Scheduler policy
# ---------------------------------------------------------------------------

def test_scheduler_priority_order_and_bucket_groups():
    sched = AdmissionScheduler()
    mk = lambda rid, n, p: Request(rid=rid, prompt=np.zeros(n, np.int64),  # noqa: E731
                                   max_new=1, priority=p)
    for r in (mk(0, 8, "batch"), mk(1, 9, "interactive"),
              mk(2, 12, "interactive"), mk(3, 20, "batch")):
        sched.enqueue(r, now=0.0)
    # head is the first INTERACTIVE despite batch arriving first; its
    # bucket (16) pulls rid 2 (bucket 16) and rid 0 (bucket 8) / rid 3
    # (bucket 32) stay queued in place
    group = sched.pop_group(max_n=4)
    assert [r.rid for r in group] == [1, 2]
    assert [r.rid for r in [sched.pop_next(), sched.pop_next()]] == [0, 3]
    assert bucket_len(9) == 16 and bucket_len(8) == 8 and bucket_len(1) == 8


def test_scheduler_slo_gates_preemption():
    sched = AdmissionScheduler(target_first_result_s=10.0)
    assert not sched.should_preempt(now=100.0)      # nothing interactive
    req = Request(rid=0, prompt=np.zeros(4, np.int64), max_new=1)
    sched.enqueue(req, now=100.0)
    assert not sched.should_preempt(now=101.0)      # wait 1s < 0.5 * SLO
    assert sched.should_preempt(now=105.0)          # wait >= 0.5 * SLO
    # without an SLO, interactive work preempts immediately
    eager = AdmissionScheduler()
    eager.enqueue(Request(rid=1, prompt=np.zeros(4, np.int64), max_new=1),
                  now=0.0)
    assert eager.should_preempt(now=0.0)


# ---------------------------------------------------------------------------
# Paged engine: equivalence, batched prefill, preemption, oversubscription
# ---------------------------------------------------------------------------

def test_paged_tokens_bit_identical_to_fixed(setup):
    """Acceptance: the paged engine's token output matches the fixed-
    partition engine on the same trace — with more requests than slots, so
    pages are freed, cleared, and reused across admissions."""
    cfg, params = setup
    reqs_d = _trace(cfg, 8)
    reqs_p = _trace(cfg, 8)
    dense = ServeEngine(cfg, params, slots=4, capacity=64)
    dense.run(reqs_d, max_steps=400)
    paged = PagedServeEngine(cfg, params, slots=4, page_size=8,
                             pages_per_slot=8, batched_prefill=False)
    paged.run(reqs_p, max_steps=400)
    assert all(r.done for r in reqs_d) and all(r.done for r in reqs_p)
    for a, b in zip(reqs_d, reqs_p):
        assert a.out == b.out, (a.rid, a.out, b.out)
    paged.pool.check()
    assert paged.pool.used_pages == 0                # everything freed


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "zamba2-7b"])
def test_paged_identity_across_cache_layouts(arch):
    """MLA caches (ckv/kr leaves) and hybrid attn+SSM caches (slot-dense
    state beside paged pages; exact-length prefill groups — padding is
    unsound for the SSM recurrence) go through the same paged paths."""
    cfg = get_config(arch, smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    mk = lambda: _trace(cfg, 4, lens=(6, 9), max_new=4, seed=11)  # noqa: E731
    reqs_d, reqs_p = mk(), mk()
    ServeEngine(cfg, params, slots=2, capacity=32).run(reqs_d, max_steps=200)
    paged = PagedServeEngine(cfg, params, slots=2, page_size=4,
                             pages_per_slot=8, batched_prefill=False)
    paged.run(reqs_p, max_steps=200)
    assert all(r.done for r in reqs_d) and all(r.done for r in reqs_p)
    for a, b in zip(reqs_d, reqs_p):
        assert a.out == b.out, (arch, a.rid, a.out, b.out)
    paged.pool.check()


def test_stall_is_value_neutral_for_ssm_state():
    """A stalled (page-less) slot's retry must be IDENTICAL: its attention
    write drops on the missing page and the ``live`` mask drops its
    SSM-state write — without it the recurrence absorbs the stalled token
    twice and a hybrid model's tokens diverge from the dense engine."""
    cfg = get_config("zamba2-7b", smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    mk = lambda: _trace(cfg, 4, lens=(6, 9), max_new=6, seed=13)  # noqa: E731
    reqs_d, reqs_p = mk(), mk()
    ServeEngine(cfg, params, slots=2, capacity=16).run(reqs_d, max_steps=300)
    paged = PagedServeEngine(cfg, params, slots=2, page_size=2,
                             pages_per_slot=8, pool_pages=8,
                             batched_prefill=False)
    stats = paged.run(reqs_p, max_steps=600)
    assert stats["stall_steps"] > 0          # pressure actually happened
    assert stats["pool_exhausted"] == 0
    assert all(r.done for r in reqs_p)
    for a, b in zip(reqs_d, reqs_p):
        assert a.out == b.out, (a.rid, a.out, b.out)


def test_batched_prefill_matches_one_slot_tokens(setup):
    """Batched multi-slot prefill (one padded executable for the whole
    admission group) must produce the same tokens as the one-slot loop."""
    cfg, params = setup
    reqs_1 = _trace(cfg, 8, seed=3)
    reqs_b = _trace(cfg, 8, seed=3)
    one = PagedServeEngine(cfg, params, slots=4, page_size=8,
                           pages_per_slot=8, batched_prefill=False)
    one.run(reqs_1, max_steps=400)
    bat = PagedServeEngine(cfg, params, slots=4, page_size=8,
                           pages_per_slot=8, batched_prefill=True)
    bat.run(reqs_b, max_steps=400)
    for a, b in zip(reqs_1, reqs_b):
        assert a.out == b.out, (a.rid, a.out, b.out)
    # the batched engine packed admissions: strictly fewer dispatches
    assert bat.stats["prefill_dispatches"] < one.stats["prefill_dispatches"]
    assert one.stats["prefill_dispatches"] == len(reqs_1)


def test_interactive_preempts_batch(setup):
    """Priority preemption ordering: batch-class work occupying the full
    pool is evicted (youngest first, requeued, restarted) the moment an
    interactive request needs the slots/pages, and the interactive request
    finishes first."""
    cfg, params = setup
    rng = np.random.default_rng(5)
    b1, b2 = (Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                      max_new=8, priority="batch") for i in (0, 1))
    eng = PagedServeEngine(cfg, params, slots=2, page_size=4,
                           pages_per_slot=4, pool_pages=4)
    eng.scheduler.enqueue(b1)
    eng.scheduler.enqueue(b2)
    assert eng._admit() == 2                        # pool now full (2x2)
    i1 = Request(rid=2, prompt=rng.integers(0, cfg.vocab, size=8), max_new=4,
                 priority="interactive")
    eng.scheduler.enqueue(i1)
    assert eng._admit() == 1                        # preempted b2 for i1
    assert b2.preemptions == 1 and b2.out == [] and b2.t_first is None
    assert any(r is i1 for r in eng.active)
    stats = eng.run([], max_steps=400)              # drain
    assert all(r.done for r in (b1, b2, i1))
    assert i1.t_done <= b2.t_done                   # interactive first
    assert stats["preemptions"] >= 1
    assert stats["classes"]["batch"]["preemptions"] >= 1
    eng.pool.check()


def test_oversubscribed_pool_completes(setup):
    """Requests >> slots over a pool well below the static partition
    (12 pages vs 4 slots x 4): everything still finishes at full budget
    (batch work preempted/requeued under pressure, pages recycled), and
    interactive p50 TTFT <= batch p50 TTFT."""
    cfg, params = setup
    reqs = _trace(cfg, 16, max_new=10, batch_every=2, seed=6)
    eng = PagedServeEngine(cfg, params, slots=4, page_size=8,
                           pages_per_slot=4, pool_pages=12)
    stats = eng.run(reqs, max_steps=3000)
    assert all(r.done for r in reqs)
    assert stats["pool_exhausted"] == 0             # never truncated
    assert all(len(r.out) == r.max_new for r in reqs)
    cls = stats["classes"]
    assert cls["interactive"]["p50_ttft_s"] <= cls["batch"]["p50_ttft_s"]
    eng.pool.check()
    assert eng.pool.used_pages == 0


def test_overflow_guard_rejects_and_clamps(setup):
    """Silent-KV-overflow fix: an unservable prompt is rejected at admit;
    a too-long generation is finished at capacity — both surfaced in
    stats, on both engines."""
    cfg, params = setup
    rng = np.random.default_rng(7)
    for mk in (lambda: ServeEngine(cfg, params, slots=2, capacity=32),
               lambda: PagedServeEngine(cfg, params, slots=2, page_size=8,
                                        pages_per_slot=4)):
        eng = mk()
        too_long = Request(rid=0, prompt=rng.integers(0, cfg.vocab, size=40),
                           max_new=4)
        clamped = Request(rid=1, prompt=rng.integers(0, cfg.vocab, size=8),
                          max_new=100)
        stats = eng.run([too_long, clamped], max_steps=400)
        assert too_long.done and not too_long.out
        assert too_long.finish_reason == "rejected_over_capacity"
        assert stats["rejected_over_capacity"] == 1
        # prompt rows [0,8) + fed-back tokens: 8 + budget - 1 <= 32
        assert clamped.done and len(clamped.out) == 32 - 8 + 1
        assert clamped.finish_reason == "capacity"
        assert stats["capacity_clamped"] == 1


def test_request_records_and_class_summary(setup):
    cfg, params = setup
    reqs = _trace(cfg, 6, max_new=4, batch_every=3, seed=8)
    eng = PagedServeEngine(cfg, params, slots=4, page_size=8,
                           pages_per_slot=8)
    stats = eng.run(reqs, max_steps=400)
    assert len(eng.records) == 6
    for rec in eng.records:
        assert rec.ttft_s > 0 and rec.n_tokens == 4
    assert set(stats["classes"]) == {"interactive", "batch"}
    assert stats["classes"]["interactive"]["n"] == 4
