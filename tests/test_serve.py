"""Batched serving engine: slot admission, continuous decode, stats."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.lm import lm_init
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen3-14b", smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_engine_completes_requests(setup):
    cfg, params = setup
    eng = ServeEngine(cfg, params, slots=4, capacity=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=6) for i in range(6)]
    stats = eng.run(reqs, max_steps=200)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= r.max_new for r in reqs)
    assert stats["admitted"] == 6
    assert stats["decoded"] > 0


def test_engine_batches_share_steps(setup):
    """Continuous batching: 4 concurrent requests must cost far fewer steps
    than 4 sequential ones (the array-launch property at the serving layer)."""
    cfg, params = setup
    rng = np.random.default_rng(1)
    eng = ServeEngine(cfg, params, slots=4, capacity=64)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, size=8),
                    max_new=10) for i in range(4)]
    stats = eng.run(reqs, max_steps=200)
    assert stats["steps"] <= 15, stats   # ~10 shared steps, not 40
