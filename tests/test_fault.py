"""Fault tolerance: checkpoint roundtrip, restart == uninterrupted run,
elastic reshard, gradient compression error feedback."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, synth_batch
from repro.runtime.compress import compress_grads, ef_init
from repro.runtime.fault import (FailureDetector, FaultConfig,
                                 HeartbeatDetector, HookDetector,
                                 WorkerFailure, resilient_train)
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_state, make_train_step

ARCH = "mamba2-1.3b"


def _setup(tmp):
    cfg = get_config(ARCH, smoke=True)
    dcfg = DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=0)))
    state = init_state(jax.random.PRNGKey(0), cfg)
    batch_fn = lambda s: {k: jnp.asarray(v)
                          for k, v in synth_batch(dcfg, s, cfg).items()}
    return step, state, batch_fn


def test_checkpoint_roundtrip(tmp_path):
    _, state, _ = _setup(tmp_path)
    ckpt.save(str(tmp_path), 7, state, blocking=True)
    restored, step = ckpt.restore(str(tmp_path), like=state)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    _, state, _ = _setup(tmp_path)
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, blocking=True, keep=2)
    assert ckpt.latest_steps(str(tmp_path)) == [4, 5]


def test_restart_equals_uninterrupted(tmp_path):
    step, state0, batch_fn = _setup(tmp_path)
    n = 6

    # uninterrupted reference
    ref = state0
    for s in range(n):
        ref, _ = step(ref, batch_fn(s))

    # failure-injected run: dies entering step 4, restores from ckpt at 2
    fails = {"armed": True}

    def failure_hook(s):
        if s == 4 and fails["armed"]:
            fails["armed"] = False
            raise WorkerFailure("injected node loss")

    fcfg = FaultConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=2,
                       async_save=False)
    out, report = resilient_train(step, state0, batch_fn, n, fcfg,
                                  failure_hook=failure_hook)
    assert report.restarts == 1
    for a, b in zip(jax.tree_util.tree_leaves(ref["params"]),
                    jax.tree_util.tree_leaves(out["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-6)


def test_elastic_reshard_preserves_values(tmp_path):
    from jax.sharding import Mesh
    from repro.runtime.elastic import reshard_state
    _, state, _ = _setup(tmp_path)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    placed = reshard_state(state, mesh)
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(placed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_error_feedback_converges():
    """With error feedback, the long-run compressed sum tracks the true sum."""
    g = {"w": jnp.full((64,), 0.003, jnp.float32)}
    err = ef_init(g)
    acc = jnp.zeros((64,))
    for _ in range(50):
        out, err = compress_grads(g, err)
        acc = acc + out["w"]
    np.testing.assert_allclose(np.asarray(acc), np.full(64, 0.15),
                               rtol=0.05)


def test_heartbeat_detector_reports_dead_worker_once():
    t = [0.0]
    det = HeartbeatDetector(timeout_s=1.0, clock=lambda: t[0])
    assert isinstance(det, FailureDetector)
    assert isinstance(HookDetector(lambda s: None), FailureDetector)
    det.beat("w0")
    det.beat("w1")
    det.check()                             # everyone fresh: no raise
    t[0] = 0.9
    det.beat("w1")
    t[0] = 1.5                              # w0 silent past the lease
    assert det.stale() == ["w0"]
    assert det.age("w0") == pytest.approx(1.5)
    assert det.age("unknown") == float("inf")
    with pytest.raises(WorkerFailure, match="w0"):
        det.check(step=5)
    det.check()                             # reported once, then forgotten
    det.beat("w0")                          # a replacement re-registers
    det.check()


def test_resilient_train_with_pluggable_detector(tmp_path):
    """The restart loop accepts any FailureDetector — here a heartbeat
    detector whose tracked worker goes silent mid-run — alongside (not
    instead of) the seed-era injection hook."""
    t = [0.0]
    det = HeartbeatDetector(timeout_s=10.0, clock=lambda: t[0])
    det.beat("node0")

    def step_fn(state, batch):
        return {"x": state["x"] + 1.0}, {}

    def batch_fn(step):
        if step == 3:
            t[0] = 99.0                     # node0's lease expires...
        return None

    fcfg = FaultConfig(ckpt_dir=str(tmp_path / "ck"), ckpt_every=2,
                       async_save=False)
    state, report = resilient_train(step_fn, {"x": jnp.zeros(())},
                                    batch_fn, 6, fcfg, detector=det)
    # ...detected entering step 4 -> restore from the last committed
    # checkpoint (step 4, saved right after step 3 ran), replay, finish
    assert report.restarts == 1
    assert report.restore_steps == [4]
    assert float(state["x"]) == 6.0


def test_compression_int8_bounds():
    from repro.runtime.compress import dequantize, quantize
    x = jnp.linspace(-3, 3, 256)
    q, s = quantize(x)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(dequantize(q, s)), np.asarray(x),
                               atol=float(s) * 0.51)
