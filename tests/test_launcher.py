"""LLMapReduce launcher invariants (the paper's mechanism), incl. hypothesis
property tests: every task runs exactly once, reduce correctness, wave
splitting, straggler re-dispatch, and serial == array == pipelined results
through the unified LaunchBackend protocol."""
import gc

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.backend import ArrayBackend, PipelinedBackend, SerialBackend
from repro.core.compile_cache import CompileCache, fingerprint
from repro.core.llmr import LLMapReduce

BACKEND_KINDS = ("serial", "array", "pipelined")


def app(x):
    return (x * 2.0).sum(axis=-1)


@pytest.fixture()
def cache(tmp_path):
    return CompileCache(cache_dir=str(tmp_path / "aot"))


def _llmr(kind, cache, **kw):
    if kind == "serial":
        return LLMapReduce(scheduler="serial", **kw)
    return LLMapReduce(scheduler=kind, cache=cache, **kw)


def _flat(out):
    if isinstance(out, list):
        return np.asarray([np.asarray(o) for o in out])
    return np.asarray(out)


@pytest.mark.parametrize("kind", ("array", "pipelined"))
@given(n=st.integers(1, 64), wave=st.integers(1, 17))
@settings(max_examples=15, deadline=None)
def test_every_task_exactly_once(kind, n, wave):
    inputs = np.arange(n * 4, dtype=np.float32).reshape(n, 4)
    llmr = LLMapReduce(wave_size=wave, scheduler=kind)
    out, report = llmr.map_reduce(app, inputs)
    np.testing.assert_allclose(_flat(out), inputs.sum(-1) * 2.0, rtol=1e-6)
    assert report.waves == -(-n // wave)
    assert report.n_instances == n


@pytest.mark.parametrize("kind", BACKEND_KINDS)
def test_backends_produce_identical_outputs(kind, cache):
    """The protocol's contract: any backend, same outputs for same inputs."""
    inputs = np.random.default_rng(0).standard_normal((12, 4)).astype(
        np.float32)
    expect = inputs.sum(-1) * 2.0
    out, report = _llmr(kind, cache, wave_size=5).map_reduce(app, inputs)
    np.testing.assert_allclose(_flat(out), expect, rtol=1e-6)
    assert report.n_instances == 12
    for rec in report.records:
        assert rec.t_first_result > 0.0          # the dead field is wired
        assert rec.t_first_result <= rec.t_spawn + 1e-9


def test_reduce_applied():
    inputs = np.ones((8, 4), np.float32)
    llmr = LLMapReduce(wave_size=4)
    out, report = llmr.map_reduce(app, inputs,
                                  reduce_fn=lambda xs: np.asarray(xs).sum())
    assert float(out) == 8 * 8.0
    assert report.t_reduce >= 0


def test_serial_equals_array_results():
    inputs = np.random.default_rng(0).standard_normal((6, 4)).astype(np.float32)
    out_a, _ = LLMapReduce(scheduler="array").map_reduce(app, inputs)
    out_s, _ = LLMapReduce(scheduler="serial").map_reduce(app, inputs)
    np.testing.assert_allclose(np.asarray(out_a),
                               np.asarray([np.asarray(o) for o in out_s]),
                               rtol=1e-6)


def test_pipelined_equals_array_results(cache):
    inputs = np.random.default_rng(3).standard_normal((32, 4)).astype(
        np.float32)
    out_a, _ = _llmr("array", cache, wave_size=8).map_reduce(app, inputs)
    out_p, rep = _llmr("pipelined", cache, wave_size=8).map_reduce(app, inputs)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_p), rtol=1e-6)
    assert rep.waves == 4
    assert rep.records[0].strategy == "llmr-pipelined"


def test_hierarchical_fanout_preserves_results(cache):
    """Two-level node/core waves: same outputs, fan-out recorded."""
    inputs = np.random.default_rng(1).standard_normal((16, 4)).astype(
        np.float32)
    be = ArrayBackend(cache=cache, inner_lanes=4)
    out, rec = be.launch(app, inputs, 16)
    np.testing.assert_allclose(np.asarray(out), inputs.sum(-1) * 2.0,
                               rtol=1e-6)
    assert rec.fanout == {"sched": 1, "node": 4, "core": 4}
    lv = rec.levels()
    assert set(lv) == {"sched", "node", "core"} and all(
        v >= 0 for v in lv.values())


def test_array_compile_cache_hits(cache):
    sched = ArrayBackend(cache=cache)
    inputs = np.ones((8, 4), np.float32)
    _, rec1 = sched.launch(app, inputs, 8)
    _, rec2 = sched.launch(app, inputs, 8)
    assert not rec1.extra["compile_cached"]
    assert rec2.extra["compile_cached"]
    assert rec2.t_schedule <= rec1.t_schedule


def test_compile_cache_key_is_content_not_id(cache):
    """Regression: the seed keyed ArrayScheduler._cache by id(fn); after
    gc, CPython reuses addresses, so a NEW function could silently get the
    OLD function's executable. The fingerprint key must not alias."""
    sched = ArrayBackend(cache=cache)
    inputs = np.ones((8, 4), np.float32)

    def make(scale):
        def fn(x, _s=scale):
            return (x * _s).sum(axis=-1)
        return fn

    f1 = make(2.0)
    fp1 = fingerprint(f1, (inputs,))
    out1, _ = sched.launch(f1, inputs, 8)
    np.testing.assert_allclose(np.asarray(out1), np.full(8, 8.0))
    old_id = id(f1)
    del f1, out1
    gc.collect()
    # allocate until the address is reused (CPython frees eagerly, so the
    # very next same-shaped function object usually lands on it)
    f2 = None
    for _ in range(64):
        cand = make(10.0)
        if id(cand) == old_id:
            f2 = cand
            break
        del cand
    if f2 is None:                 # address not reused on this runtime:
        f2 = make(10.0)            # still assert key soundness below
    assert fingerprint(f2, (inputs,)) != fp1
    out2, _ = sched.launch(f2, inputs, 8)
    np.testing.assert_allclose(np.asarray(out2), np.full(8, 40.0))


def test_fingerprint_hashes_closure_array_values(cache):
    """Regression: jit bakes closed-over arrays into the program as
    constants, so two closures over same-shaped but different-valued
    weights are DIFFERENT programs and must not alias in the cache."""
    sched = ArrayBackend(cache=cache)
    inputs = np.ones((4, 3), np.float32)

    def make(w):
        def fn(v):
            return (v * w).sum(axis=-1)
        return fn

    f1 = make(np.full(3, 2.0, np.float32))
    f2 = make(np.full(3, 10.0, np.float32))
    assert fingerprint(f1, (inputs,)) != fingerprint(f2, (inputs,))
    out1, _ = sched.launch(f1, inputs, 4)
    out2, _ = sched.launch(f2, inputs, 4)
    np.testing.assert_allclose(np.asarray(out1), np.full(4, 6.0))
    np.testing.assert_allclose(np.asarray(out2), np.full(4, 30.0))


def test_fingerprint_sees_indirect_closure_values():
    """Regression: a launched fn may CALL an inner function whose closure
    holds the data; the fingerprint must reach one level through the
    referenced callable, not just hash its bytecode."""
    inputs = np.ones((4, 3), np.float32)

    def make(w):
        def inner(x):
            return x * w

        def outer(x):
            return inner(x).sum(axis=-1)
        return outer

    f1 = make(np.full(3, 2.0, np.float32))
    f2 = make(np.full(3, 10.0, np.float32))
    assert fingerprint(f1, (inputs,)) != fingerprint(f2, (inputs,))


def test_fingerprint_sees_arrays_inside_containers():
    """Regression: a closed-over params DICT must contribute its arrays'
    VALUES to the key (repr of a large array truncates to corner values,
    which would alias different weights)."""
    inputs = np.ones((4, 64), np.float32)

    def make(params):
        def fn(x):
            return (x @ params["w"]).sum(axis=-1)
        return fn

    w1 = np.zeros((64, 64), np.float32)
    w2 = np.zeros((64, 64), np.float32)
    w2[10, 10] = 99.0              # interior change: repr() is identical
    assert (fingerprint(make({"w": w1}), (inputs,))
            != fingerprint(make({"w": w2}), (inputs,)))
    # and a change past any truncation horizon of a LARGE container
    big1 = {f"w{i}": np.float32(i) for i in range(24)}
    big2 = dict(big1, w20=np.float32(999.0))
    assert (fingerprint(make(big1), (inputs,))
            != fingerprint(make(big2), (inputs,)))


_SCALE = 2.0


def test_fingerprint_tracks_global_rebinding():
    """Regression: rebinding a module global referenced by the launched fn
    must change the key (no stale memoized digest)."""
    global _SCALE

    def fn(x):
        return x * _SCALE

    a = np.ones((4, 3), np.float32)
    _SCALE = 2.0
    fp1 = fingerprint(fn, (a,))
    try:
        _SCALE = 10.0
        assert fingerprint(fn, (a,)) != fp1
    finally:
        _SCALE = 2.0


def test_fingerprint_stable_and_shape_sensitive():
    a = np.ones((8, 4), np.float32)
    assert fingerprint(app, (a,)) == fingerprint(app, (a,))
    assert fingerprint(app, (a,)) != fingerprint(app, (np.ones((4, 4),
                                                             np.float32),))


def test_fingerprint_tracks_in_place_array_mutation():
    """Closed-over array VALUES are part of the key even when mutated in
    place (the memoization fast path must not capture a stale digest)."""
    w = np.full(3, 2.0, np.float32)

    def fn(x):
        return (x * w).sum(axis=-1)

    a = np.ones((4, 3), np.float32)
    fp1 = fingerprint(fn, (a,))
    assert fingerprint(fn, (a,)) == fp1      # repeat call: same key
    w[:] = 10.0                              # in-place mutation
    assert fingerprint(fn, (a,)) != fp1


def test_compile_cache_persists_across_instances(tmp_path):
    """A fresh CompileCache over the same dir = a new process: the warm
    path must come from disk and skip compile."""
    d = str(tmp_path / "aot")
    inputs = np.ones((8, 4), np.float32)
    _, rec1 = ArrayBackend(cache=CompileCache(cache_dir=d)).launch(
        app, inputs, 8)
    _, rec2 = ArrayBackend(cache=CompileCache(cache_dir=d)).launch(
        app, inputs, 8)
    assert rec1.extra["compile_source"] == "compiled"
    assert rec2.extra["compile_source"] == "disk"
    assert rec2.t_schedule < rec1.t_schedule


def test_straggler_speculative_redispatch():
    inputs = np.ones((16, 4), np.float32)
    llmr = LLMapReduce(wave_size=4, straggler_factor=2.0)
    delays = {2: 1.0}  # third wave is a straggler

    out, report = llmr.map_reduce(
        app, inputs, wave_delay_hook=lambda w: delays.get(w, 0.0))
    assert report.speculative_redispatches >= 1
    np.testing.assert_allclose(np.asarray(out), np.full(16, 8.0), rtol=1e-6)


def test_straggler_accounting_keeps_both_attempts():
    """Regression: the seed dropped the re-run's record, so the first
    attempt's cost vanished from the report. Both attempts must appear,
    but instances are only counted once."""
    inputs = np.ones((16, 4), np.float32)
    llmr = LLMapReduce(wave_size=4, straggler_factor=2.0)
    _, report = llmr.map_reduce(
        app, inputs, wave_delay_hook=lambda w: {2: 1.0}.get(w, 0.0))
    assert report.speculative_redispatches >= 1
    superseded = [r for r in report.records
                  if r.extra.get("superseded_by_redispatch")]
    reruns = [r for r in report.records
              if r.extra.get("straggler_redispatch")]
    assert len(superseded) == report.speculative_redispatches
    assert len(reruns) == report.speculative_redispatches
    assert len(report.records) == report.waves + report.speculative_redispatches
    assert report.n_instances == 16                       # no double count
    assert report.n_attempts == 16 + 4 * len(reruns)      # cost retained
    # the straggler attempt's wall time (incl. its delay) stays visible
    assert superseded[0].extra["t_wave"] > reruns[0].extra["t_wave"]


def test_pipelined_straggler_redispatch_without_barrier(cache):
    """Tentpole regression: with depth>=2 and one injected slow wave, the
    driver must (a) keep harvesting other waves while a speculative
    duplicate races the straggler — no harvest barrier, (b) count the
    work once while keeping both attempts' cost, and (c) produce
    bit-identical results to the clean run."""
    inputs = np.arange(64 * 4, dtype=np.float32).reshape(64, 4)

    def mk():
        return LLMapReduce(wave_size=8, straggler_factor=3.0,
                           min_straggler_s=0.05,
                           backend=PipelinedBackend(cache=cache, depth=2))

    out_ref, _ = mk().map_reduce(app, inputs)
    delay = 1.5
    out, report = mk().map_reduce(
        app, inputs, wave_delay_hook=lambda w: delay if w == 3 else 0.0)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_ref))
    assert report.speculative_redispatches >= 1
    assert report.waves == 8
    # work counted ONCE; every attempt's cost retained
    assert report.n_instances == 64
    assert report.n_attempts == 64 + 8 * report.speculative_redispatches
    # barrier-free: the run never paid the injected delay (the old
    # synchronous re-dispatch inside harvest() cost the full delay)
    assert report.t_total < delay, report.t_total
    # later waves were harvested while the duplicate was in flight
    order = [r.extra["wave"] for r in report.records if not r.superseded]
    assert order.index(3) > order.index(4)
    superseded = [r for r in report.records if r.superseded]
    winners = [r for r in report.records if r.redispatch]
    assert any(r.extra["wave"] == 3 for r in superseded)
    assert any(r.extra["wave"] == 3 for r in winners)
    # the loser's record keeps its (partial) wall clock, never blocking
    # the driver for it
    assert all(r.extra["t_wave"] > 0 for r in superseded)


def test_launch_rate_array_beats_serial():
    """The paper's headline property at CPU scale: array launch must beat
    serial-VM launch by a wide margin."""
    inputs = np.ones((64, 8), np.float32)
    import time
    t0 = time.perf_counter()
    LLMapReduce(scheduler="array").map_reduce(app, inputs)
    t_array = time.perf_counter() - t0
    t0 = time.perf_counter()
    LLMapReduce(scheduler="serial").map_reduce(app, inputs)
    t_serial = time.perf_counter() - t0
    assert t_serial > 3.0 * t_array, (t_serial, t_array)


def test_deprecated_scheduler_shim_is_gone():
    """The seed-era ``repro.core.scheduler`` shim (deprecated since the
    transport-fabric PR) is removed for good: importing it must fail —
    every caller goes through ``make_backend``."""
    with pytest.raises(ImportError):
        import repro.core.scheduler  # noqa: F401


def test_launch_model_headline():
    from repro.core.launch_model import (copy_time, headline,
                                         launch_time_azure,
                                         launch_time_llmr)
    h = headline()
    # paper: 16,384 Windows instances in ~5 minutes
    assert h["within_1p5x"], h
    # Fig 6 ordering: llmr << azure at every N
    for n in (16, 256, 4096, 16384):
        assert launch_time_llmr(n) < launch_time_azure(n)
    # Fig 5: copy time stays small relative to launch time
    assert copy_time(16384) < 0.2 * launch_time_llmr(16384)


@given(st.integers(1, 14))
def test_launch_model_monotone(k):
    from repro.core.launch_model import CURVES
    n = 2 ** k
    for fn in CURVES.values():
        assert fn(2 * n) >= fn(n) * 0.999  # time nondecreasing in N
