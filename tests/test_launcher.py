"""LLMapReduce launcher invariants (the paper's mechanism), incl. hypothesis
property tests: every task runs exactly once, reduce correctness, wave
splitting, straggler re-dispatch, serial == array results."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.llmr import LLMapReduce
from repro.core.scheduler import ArrayScheduler, SerialScheduler


def app(x):
    return (x * 2.0).sum(axis=-1)


@given(n=st.integers(1, 64), wave=st.integers(1, 17))
@settings(max_examples=15, deadline=None)
def test_every_task_exactly_once(n, wave):
    inputs = np.arange(n * 4, dtype=np.float32).reshape(n, 4)
    llmr = LLMapReduce(wave_size=wave)
    out, report = llmr.map_reduce(app, inputs)
    np.testing.assert_allclose(np.asarray(out), inputs.sum(-1) * 2.0,
                               rtol=1e-6)
    assert report.waves == -(-n // wave)
    assert report.n_instances == n


def test_reduce_applied():
    inputs = np.ones((8, 4), np.float32)
    llmr = LLMapReduce(wave_size=4)
    out, report = llmr.map_reduce(app, inputs,
                                  reduce_fn=lambda xs: np.asarray(xs).sum())
    assert float(out) == 8 * 8.0
    assert report.t_reduce >= 0


def test_serial_equals_array_results():
    inputs = np.random.default_rng(0).standard_normal((6, 4)).astype(np.float32)
    out_a, _ = LLMapReduce(scheduler="array").map_reduce(app, inputs)
    out_s, _ = LLMapReduce(scheduler="serial").map_reduce(app, inputs)
    np.testing.assert_allclose(np.asarray(out_a),
                               np.asarray([np.asarray(o) for o in out_s]),
                               rtol=1e-6)


def test_array_compile_cache_hits():
    sched = ArrayScheduler()
    inputs = np.ones((8, 4), np.float32)
    _, rec1 = sched.launch(app, inputs, 8)
    _, rec2 = sched.launch(app, inputs, 8)
    assert not rec1.extra["compile_cached"]
    assert rec2.extra["compile_cached"]
    assert rec2.t_schedule <= rec1.t_schedule


def test_straggler_speculative_redispatch():
    inputs = np.ones((16, 4), np.float32)
    llmr = LLMapReduce(wave_size=4, straggler_factor=2.0)
    delays = {2: 1.0}  # third wave is a straggler

    out, report = llmr.map_reduce(
        app, inputs, wave_delay_hook=lambda w: delays.get(w, 0.0))
    assert report.speculative_redispatches >= 1
    np.testing.assert_allclose(np.asarray(out), np.full(16, 8.0), rtol=1e-6)


def test_launch_rate_array_beats_serial():
    """The paper's headline property at CPU scale: array launch must beat
    serial-VM launch by a wide margin."""
    inputs = np.ones((64, 8), np.float32)
    import time
    t0 = time.perf_counter()
    LLMapReduce(scheduler="array").map_reduce(app, inputs)
    t_array = time.perf_counter() - t0
    t0 = time.perf_counter()
    LLMapReduce(scheduler="serial").map_reduce(app, inputs)
    t_serial = time.perf_counter() - t0
    assert t_serial > 3.0 * t_array, (t_serial, t_array)


def test_launch_model_headline():
    from repro.core.launch_model import (copy_time, headline,
                                         launch_time_azure,
                                         launch_time_llmr)
    h = headline()
    # paper: 16,384 Windows instances in ~5 minutes
    assert h["within_1p5x"], h
    # Fig 6 ordering: llmr << azure at every N
    for n in (16, 256, 4096, 16384):
        assert launch_time_llmr(n) < launch_time_azure(n)
    # Fig 5: copy time stays small relative to launch time
    assert copy_time(16384) < 0.2 * launch_time_llmr(16384)


@given(st.integers(1, 14))
def test_launch_model_monotone(k):
    from repro.core.launch_model import CURVES
    n = 2 ** k
    for fn in CURVES.values():
        assert fn(2 * n) >= fn(n) * 0.999  # time nondecreasing in N
