"""Content-addressed chunked staging: digest/chunk primitives, the LRU
chunk cache under pressure, the scheduler-side dedup directory, and the
fabric-level contracts — repeat waves re-send (almost) nothing, a corrupt
chunk fails exactly its shard with a loud ``ProtocolError`` (never a
silent corrupt stage), an evicted chunk is transparently re-requested
with exactly-once results, and a dead/suspect peer degrades to the
authoritative scheduler re-send instead of wedging the wave."""
import threading

import numpy as np
import pytest

from repro.core.compile_cache import CompileCache
from repro.dist import DistributedBackend
from repro.dist.chunks import (ChunkCache, ChunkDirectory, chunk_digest,
                               chunk_split)
from repro.dist.node import NodeAgent
from repro.dist.registry import NodeRegistry
from repro.dist.transport import CHUNK


def app(x):
    return (x * 3.0).sum(axis=-1)


_GATE = threading.Event()


def gated_app(x):
    """Holds the wave open until the test releases ``_GATE`` — module
    level so it pickles over the socket wire."""
    _GATE.wait(5.0)
    return (x * 3.0).sum(axis=-1)


@pytest.fixture()
def cache(tmp_path):
    return CompileCache(cache_dir=str(tmp_path / "aot"))


@pytest.fixture(params=["inproc", "socket"])
def transport(request):
    return request.param


def _fabric(cache, n_nodes=2, **kw):
    kw.setdefault("heartbeat_s", 0.02)
    kw.setdefault("heartbeat_timeout_s", 10.0)
    return DistributedBackend(n_nodes=n_nodes, cache=cache, **kw)


# ----------------------------------------------------------------------
# primitives
# ----------------------------------------------------------------------

def test_chunk_split_roundtrip_and_digest_stability():
    blob = bytes(range(256)) * 100
    parts = chunk_split(blob, 1000)
    assert b"".join(parts) == blob
    assert all(len(p) == 1000 for p in parts[:-1])
    # identical bytes -> identical key; different bytes -> different key
    assert chunk_digest(parts[0]) == chunk_digest(bytes(parts[0]))
    assert chunk_digest(parts[0]) != chunk_digest(parts[0][:-1] + b"x")
    assert chunk_split(b"", 100) == [b""]
    with pytest.raises(ValueError):
        chunk_split(blob, 0)


def test_chunk_cache_lru_eviction_spares_pins():
    c = ChunkCache(max_bytes=100)
    keys = []
    for i in range(5):
        data = bytes([i]) * 40
        d = chunk_digest(data)
        keys.append(d)
        c.put(d, data)
    # 5 x 40 bytes into a 100-byte budget: only the 2 newest survive
    assert c.total_bytes == 80
    assert c.get(keys[0]) is None and c.get(keys[4]) is not None
    assert c.stats["evictions"] == 3
    # a pinned chunk survives pressure that would otherwise evict it
    c.pin([keys[4]])
    for i in range(5, 9):
        data = bytes([i]) * 40
        c.put(chunk_digest(data), data)
    assert c.holds(keys[4])             # holds() does not refresh recency
    c.unpin([keys[4]])
    for ch in (b"y", b"z"):
        c.put(chunk_digest(ch * 40), ch * 40)
    assert not c.holds(keys[4])         # unpinned: LRU reclaims it


def test_chunk_cache_hit_refreshes_recency():
    c = ChunkCache(max_bytes=100)
    a, b = b"a" * 40, b"b" * 40
    da, db = chunk_digest(a), chunk_digest(b)
    c.put(da, a)
    c.put(db, b)
    assert c.get(da) == a               # refresh: a is now the newest
    c.put(chunk_digest(b"c" * 40), b"c" * 40)
    assert c.get(da) is not None        # b was evicted, not a
    assert c.get(db) is None


def test_stage_parts_digests_invariant_to_shard_boundaries():
    """Row groups align to the GLOBAL offset: however a wave is split,
    interior groups of the same rows hash identically — the property
    that keeps repeat waves byte-free after re-weighting shifts shards."""
    arr = np.arange(24 * 256, dtype=np.float32).reshape(24, 256)
    eff = 4 * arr[0].nbytes             # 4 rows per group
    whole = {chunk_digest(p)
             for p in NodeAgent._stage_parts(arr, eff, 0)[1]}
    # a shard covering global rows [6, 18) at its true offset
    mode, parts = NodeAgent._stage_parts(arr[6:18], eff, 6)
    assert mode == "rows"
    digests = [chunk_digest(p) for p in parts]
    # its interior groups ([8,12) and [12,16)) appear in the whole-wave
    # digest set; only the two boundary groups are shard-specific
    assert len(set(digests) & whole) >= 2
    # reassembly is exact
    import pickle
    groups = [pickle.loads(p) for p in parts]
    np.testing.assert_array_equal(np.concatenate(groups), arr[6:18])


def test_stage_parts_blob_fallback_for_pytrees():
    mode, parts = NodeAgent._stage_parts({"w": np.ones(8)}, 1 << 20, 0)
    assert mode == "blob"
    import pickle
    out = pickle.loads(b"".join(parts))
    np.testing.assert_array_equal(out["w"], np.ones(8))


# ----------------------------------------------------------------------
# directory: the dedup decision
# ----------------------------------------------------------------------

def test_directory_plan_wire_then_peer_then_cached():
    reg = NodeRegistry(heartbeat_timeout_s=100.0)
    for nid in ("n0", "n1", "n2"):
        reg.register(nid)
    d = ChunkDirectory(reg, node_cache_bytes=1 << 20)
    d.set_peer("n0", ("tcp", ("127.0.0.1", 1)))
    dig = chunk_digest(b"x" * 100)
    assert d.plan("n0", dig, 100) == "wire"        # first holder
    plan = d.plan("n1", dig, 100)                  # hinted at the holder
    assert plan == ("peer", ("tcp", ("127.0.0.1", 1)))
    assert d.plan("n1", dig, 100) == "cached"      # now modeled as held
    # a suspect/dead holder is never hinted: degrade to direct send
    reg.nodes["n0"].state = "suspect"
    dig2 = chunk_digest(b"y" * 100)
    assert d.plan("n0", dig2, 100) == "wire"
    assert d.plan("n2", dig2, 100) == "wire"       # only holder not alive


def test_directory_forget_and_drop_node_correct_the_model():
    reg = NodeRegistry(heartbeat_timeout_s=100.0)
    reg.register("n0")
    reg.register("n1")
    d = ChunkDirectory(reg, node_cache_bytes=1 << 20)
    d.set_peer("n0", ("tcp", ("127.0.0.1", 1)))
    dig = chunk_digest(b"x" * 100)
    assert d.plan("n0", dig, 100) == "wire"
    d.forget("n0", [dig])                          # node evicted it
    assert d.plan("n0", dig, 100) == "wire"        # honest re-send
    assert d.plan("n1", dig, 100)[0] == "peer"
    d.drop_node("n0")                              # holder died
    d.forget("n1", [dig])
    assert d.plan("n1", dig, 100) == "wire"        # no holder remains


def test_directory_held_model_mirrors_node_budget():
    d = ChunkDirectory(None, node_cache_bytes=100)
    digs = [chunk_digest(bytes([i]) * 40) for i in range(4)]
    for dig in digs:
        assert d.plan("n0", dig, 40) == "wire"
    # the model's LRU evicted the oldest entries along with the node
    assert d.plan("n0", digs[0], 40) == "wire"     # believed evicted
    assert d.plan("n0", digs[-1], 40) == "cached"  # believed resident


# ----------------------------------------------------------------------
# fabric: repeat waves, corruption, eviction, dead peers
# ----------------------------------------------------------------------

def test_repeat_wave_resends_almost_nothing(cache):
    """The tentpole's measured win: an identical-payload wave over the
    socket wire dedups within the wave (bytes-on-wire well under bytes
    delivered) and across waves (a repeat re-sends only manifests)."""
    x = np.tile(np.arange(2048, dtype=np.float32), (64, 1))
    # reweight_deadband=1.0 pins the split at declared capacity: under
    # full-suite load the measured-cost EWMA can shift shard boundaries
    # between waves, and the partial head/tail row groups at the moved
    # boundaries mint fresh digests — this test measures dedup, not
    # re-weighting (which has its own coverage in test_dist.py)
    be = _fabric(cache, n_nodes=4, transport="socket",
                 chunk_bytes=64 << 10, reweight_deadband=1.0)
    try:
        wires = []
        for _ in range(3):
            out, rec = be.launch(app, x, 64)
            np.testing.assert_allclose(np.asarray(out), app(x), rtol=1e-5)
            st = rec.extra["stage"]
            assert st["bytes_delivered"] > 0
            wires.append(st["bytes_on_wire"])
        # within-wave dedup: 4 identical shards cost well under 4x one
        assert wires[0] < 0.5 * st["bytes_delivered"]
        # across-wave dedup: a repeat wave re-sends <10% of the first
        assert wires[-1] < 0.10 * wires[0], wires
        dd = st["dedup"]
        for key in ("chunks", "from_cache", "from_wire", "from_peer",
                    "requested", "cache_hit_rate", "peer_bytes"):
            assert key in dd, key
        assert dd["from_cache"] > 0     # repeat wave hit the node caches
    finally:
        be.close()


def test_corrupt_chunk_is_a_loud_protocol_error(cache, transport):
    """Satellite contract: a chunk whose bytes do not hash to the
    manifest digest fails exactly that shard with ``ProtocolError`` in
    the error chain — never a silent corrupt stage — and the node
    survives to serve the next wave. Both transports."""
    rng = np.random.default_rng(7)
    x = rng.standard_normal((32, 512)).astype(np.float32)
    be = _fabric(cache, n_nodes=2, transport=transport,
                 chunk_bytes=16 << 10)
    victim = be.agents["node0"]
    real_send = victim._ch.send
    corrupted = []

    def bad_send(kind, payload):
        if kind == CHUNK and not corrupted and payload.get("data"):
            corrupted.append(payload["d"])
            payload = dict(payload,
                           data=b"\x00" * len(payload["data"]))
        return real_send(kind, payload)

    victim._ch.send = bad_send
    try:
        with pytest.raises(RuntimeError, match="digest mismatch"):
            be.launch(app, x, 32)
        assert corrupted                # the corruption really happened
        victim._ch.send = real_send
        # the node is alive and the fabric serves the next wave cleanly
        out, rec = be.launch(app, x, 32)
        np.testing.assert_allclose(np.asarray(out), app(x), rtol=1e-4,
                                   atol=1e-4)
    finally:
        victim._ch.send = real_send
        be.close()


def test_evicted_chunk_is_rerequested_transparently(cache, transport):
    """Memory pressure on a node (its chunk cache dropped between waves)
    must be invisible to the caller: the scheduler's optimistic held
    model says 'cached', the node answers with CHUNK_REQ, the
    authoritative store re-sends, and results stay exactly-once."""
    x = np.tile(np.arange(2048, dtype=np.float32), (32, 1))
    be = _fabric(cache, n_nodes=2, transport=transport,
                 chunk_bytes=32 << 10)
    try:
        out, _ = be.launch(app, x, 32)
        np.testing.assert_allclose(np.asarray(out), app(x), rtol=1e-5)
        # simulate pressure: every node loses its whole chunk cache
        for agent in be.agents.values():
            assert agent._ctl.chunk_cache is not None
            agent._ctl.chunk_cache.clear()
        before = be.directory.stats["resends"]
        out, rec = be.launch(app, x, 32)
        np.testing.assert_allclose(np.asarray(out), app(x), rtol=1e-5)
        assert len(np.asarray(out)) == 32          # exactly once
        assert be.directory.stats["resends"] > before
        assert rec.extra["stage"]["dedup"]["requested"] > 0
    finally:
        be.close()


def test_dead_peer_falls_back_to_scheduler(cache, monkeypatch):
    """A peer that never answers (died mid-transfer) costs latency, not
    the wave: every hinted fetch fails, the node falls back to one
    CHUNK_REQ, and the authoritative store delivers."""
    import repro.dist.chunks as chunks_mod
    monkeypatch.setattr(chunks_mod, "peer_fetch",
                        lambda spec, digest, timeout_s=3.0: None)
    x = np.tile(np.arange(2048, dtype=np.float32), (48, 1))
    be = _fabric(cache, n_nodes=3, transport="socket",
                 chunk_bytes=64 << 10)
    try:
        out, rec = be.launch(app, x, 48)
        np.testing.assert_allclose(np.asarray(out), app(x), rtol=1e-5)
        dd = rec.extra["stage"]["dedup"]
        assert dd["from_peer"] == 0         # nobody fetched from a peer
        assert dd["requested"] > 0          # the fallback path really ran
    finally:
        be.close()


def test_node_death_mid_wave_restages_from_scheduler(cache):
    """A node killed mid-chunk-transfer: its shard fails over to a
    survivor, whose payload is re-staged from the scheduler's
    authoritative store (the dead peer serves nothing), and the wave
    completes exactly-once with dedup telemetry intact."""
    x = np.tile(np.arange(2048, dtype=np.float32), (48, 1))
    be = _fabric(cache, n_nodes=3, transport="socket",
                 heartbeat_timeout_s=0.6, chunk_bytes=64 << 10)
    try:
        be.launch(app, x, 48)                    # warm: peers hold chunks
        _GATE.clear()
        handle = be.dispatch(gated_app, x, 48)
        be.agents["node2"].kill()                # dies mid-wave
        _GATE.set()
        out, rec = handle.result()
        np.testing.assert_allclose(np.asarray(out), app(x), rtol=1e-5)
        assert len(np.asarray(out)) == 48        # exactly once
        assert rec.extra.get("node_failure") is True
        assert rec.extra["stage"]["dedup"]["chunks"] > 0
    finally:
        be.close()


def test_stage_dedup_off_is_a_clean_baseline(cache):
    """``stage_dedup=False`` (the A/B switch ``examples/massive_launch``
    exposes) keeps the PR-5 whole-payload path: correct results, byte
    accounting still present, no dedup rollup."""
    x = np.tile(np.arange(2048, dtype=np.float32), (32, 1))
    be = _fabric(cache, n_nodes=2, transport="socket", stage_dedup=False)
    try:
        out, rec = be.launch(app, x, 32)
        np.testing.assert_allclose(np.asarray(out), app(x), rtol=1e-5)
        st = rec.extra["stage"]
        assert st["bytes_on_wire"] >= st["bytes_delivered"] > 0
        assert "dedup" not in st
        assert be.directory is None
    finally:
        be.close()
