"""Transport failure matrix: codec roundtrips, oversized-payload
rejection (send-side cap and poisoned length prefixes), out-of-order and
zombie RESULT frames, a connection dropped mid-shard reading as node
death with exactly-once preserved, SIGTERM'd process nodes behaving
identically over sockets and queues, and the node-side ``Stager``'s
overlap accounting."""
import socket
import time

import numpy as np
import pytest

from repro.core.compile_cache import CompileCache
from repro.core.staging import Stager
from repro.core.telemetry import LaunchRecord
from repro.dist import (DEAD, DistributedBackend, NodeAgent, NodeRegistry,
                        PayloadTooLarge, ProtocolError, SocketTransport)
from repro.dist.transport import (HEARTBEAT, RESULT, InprocTransport,
                                  SocketChannel, _decode, _encode,
                                  open_worker_channel)


def app(x):
    return (x * 3.0).sum(axis=-1)


@pytest.fixture()
def cache(tmp_path):
    return CompileCache(cache_dir=str(tmp_path / "aot"))


# ----------------------------------------------------------------------
# codec + framing
# ----------------------------------------------------------------------

def test_codec_picks_msgpack_for_control_pickle_for_data():
    codec, body = _encode({"node": "n0", "beat": 3})
    assert _decode(codec, body) == {"node": "n0", "beat": 3}
    arr = np.arange(6, dtype=np.float32)
    codec, body = _encode({"chunk": arr})
    assert codec == b"P"                    # arrays need pickle
    np.testing.assert_array_equal(_decode(codec, body)["chunk"], arr)
    assert _decode(*_encode(None)) is None
    with pytest.raises(ProtocolError):
        _decode(b"?", b"")


def _socket_pair(max_frame_bytes=1 << 20):
    """A raw connected channel pair over loopback (no agent on top)."""
    tr = SocketTransport(max_frame_bytes=max_frame_bytes)
    port = tr.create("n0")
    worker = open_worker_channel(port.endpoint)
    driver = port.driver_channel(timeout=5.0)
    return tr, driver, worker


def test_socket_frames_roundtrip_and_interleave():
    tr, driver, worker = _socket_pair()
    try:
        worker.send(HEARTBEAT, "n0")
        worker.send(RESULT, {"task_id": 1, "ok": True,
                             "out": np.ones(3), "rec": None})
        f1 = driver.recv(timeout=2.0)
        f2 = driver.recv(timeout=2.0)
        assert f1.kind == HEARTBEAT and f1.payload == "n0"
        assert f2.kind == RESULT and f2.payload["task_id"] == 1
        np.testing.assert_array_equal(f2.payload["out"], np.ones(3))
        assert driver.recv(timeout=0.05) is None      # timeout, not EOF
    finally:
        driver.close()
        worker.close()
        tr.close()


def test_oversized_payload_rejected_at_send_socket():
    tr, driver, worker = _socket_pair(max_frame_bytes=4096)
    try:
        with pytest.raises(PayloadTooLarge):
            driver.send(RESULT, {"blob": np.zeros(64 * 1024, np.uint8)})
        # the channel survives a rejected send — small frames still flow
        driver.send(HEARTBEAT, "driver")
        assert worker.recv(timeout=2.0).kind == HEARTBEAT
    finally:
        driver.close()
        worker.close()
        tr.close()


def test_oversized_length_prefix_poisons_the_connection():
    """A length prefix past the cap must raise ``ProtocolError`` and
    close the connection instead of allocating unbounded memory."""
    a, b = socket.socketpair()
    ch = SocketChannel(a, max_frame_bytes=4096)
    b.sendall((1 << 30).to_bytes(4, "big") + b"garbage")
    with pytest.raises(ProtocolError):
        ch.recv(timeout=2.0)
    assert ch.closed
    b.close()


def test_oversized_payload_rejected_inproc():
    port = InprocTransport(max_frame_bytes=1024).create("n0")
    driver = port.driver_channel()
    with pytest.raises(PayloadTooLarge):
        driver.send(RESULT, {"blob": np.zeros(8192, np.uint8)})


def app_big_out(x):
    """Output ~50x the input: blows a small frame cap on the RESULT."""
    import jax.numpy as jnp
    return jnp.zeros((x.shape[0], 50_000), jnp.float32)


def test_unpicklable_fn_over_socket_fails_loudly(cache):
    """A shard fn that cannot serialize (a lambda) must fail THAT shard
    with the pickling error — not silently kill the send thread and hang
    the wave forever (the node keeps heartbeating, so no lease expiry
    would ever have rescued it)."""
    be = DistributedBackend(n_nodes=1, cache=cache, transport="socket",
                            heartbeat_timeout_s=10.0)
    with pytest.raises(Exception, match="[Pp]ickl"):
        be.launch(lambda x: x * 2.0, np.ones((4, 2), np.float32), 4)
    # the channel survived: a well-formed launch still works
    out, _ = be.launch(app, np.ones((4, 2), np.float32), 4)
    np.testing.assert_allclose(np.asarray(out), np.full(4, 6.0))
    be.close()


def test_oversized_result_reports_error_not_hang(cache):
    """A RESULT too big for the frame cap must come back as the (tiny)
    error form — the scheduler hears SOMETHING, instead of a forever-
    pending future on a healthy, heartbeating node."""
    tr = SocketTransport(max_frame_bytes=100_000)
    be = DistributedBackend(n_nodes=1, cache=cache, transport=tr,
                            heartbeat_timeout_s=10.0)
    with pytest.raises(RuntimeError, match="PayloadTooLarge"):
        be.launch(app_big_out, np.ones((4, 2), np.float32), 4)
    be.close()


def test_oversized_shard_fails_the_wave_loudly(cache):
    """An oversized shard payload must surface as that wave's error (the
    STAGE frame is rejected before the wire, its SUBMIT is skipped, and
    the handle raises) — not hang, not truncate."""
    tr = SocketTransport(max_frame_bytes=50_000)
    be = DistributedBackend(n_nodes=2, cache=cache, transport=tr,
                            heartbeat_timeout_s=10.0)
    inputs = np.ones((256, 256), np.float32)     # ~128 KB per shard
    with pytest.raises(PayloadTooLarge):
        be.launch(app, inputs, 256)
    be.close()


# ----------------------------------------------------------------------
# scheduler-side pump: ordering, zombies
# ----------------------------------------------------------------------

def test_out_of_order_and_zombie_result_frames(cache):
    """RESULT frames are matched by task id, not arrival order, and a
    frame for an already-resolved (or unknown) task is dropped — the
    exactly-once guarantee at the frame level."""
    reg = NodeRegistry(heartbeat_timeout_s=5.0)
    agent = NodeAgent("n0", reg, cache=cache, heartbeat_s=0.02)
    agent.pause()                           # nothing really executes
    chunk = np.ones((4, 2), np.float32)
    t1 = agent.submit(app, chunk, 4)
    t2 = agent.submit(app, chunk, 4)
    wch = agent._port.endpoint[1]           # the worker-side channel
    wch.send(RESULT, {"task_id": t2.task_id, "ok": True, "out": "B",
                      "rec": LaunchRecord("fake", 4)})
    wch.send(RESULT, {"task_id": t1.task_id, "ok": True, "out": "A",
                      "rec": LaunchRecord("fake", 4)})
    deadline = time.perf_counter() + 5.0
    while not (t1.ready and t2.ready) and time.perf_counter() < deadline:
        time.sleep(0.005)
    assert t1.out == "A" and t2.out == "B"  # order did not matter
    # a zombie re-delivery of t1 must not clobber the resolved future
    wch.send(RESULT, {"task_id": t1.task_id, "ok": True, "out": "Z",
                      "rec": LaunchRecord("fake", 4)})
    wch.send(RESULT, {"task_id": 999999, "ok": True, "out": "?",
                      "rec": LaunchRecord("fake", 4)})
    time.sleep(0.1)
    assert t1.out == "A"
    agent.kill()


# ----------------------------------------------------------------------
# dead connections and dead processes
# ----------------------------------------------------------------------

def test_connection_dropped_mid_shard_reads_as_node_death(cache):
    """Sever the TCP connection while a shard executes: the scheduler
    must condemn the node immediately (dead connection ≡ lease expiry),
    fail the shard over, and keep results exactly-once — the severed
    node's late result has no path back."""
    be = DistributedBackend(n_nodes=2, cache=cache, transport="socket",
                            heartbeat_timeout_s=30.0)   # lease can't save it
    inputs = np.random.default_rng(0).standard_normal((24, 8)).astype(
        np.float32)
    be.launch(app, inputs, 24)              # warm both nodes
    be.agents["node1"].throttle(0.5)        # shard will be mid-flight
    handle = be.dispatch(app, inputs, 24)
    time.sleep(0.1)                         # node1 is inside its shard
    be.agents["node1"]._ch._sock.shutdown(socket.SHUT_RDWR)  # partition
    out, rec = handle.result()
    np.testing.assert_allclose(np.asarray(out), inputs.sum(-1) * 3.0,
                               rtol=1e-5, atol=1e-4)
    assert be.registry.state("node1") == DEAD
    assert be.registry.nodes["node1"].failures == 1
    assert rec.extra.get("failover")        # the shard moved to node0
    assert rec.extra["node_failure"] is True
    be.close()


@pytest.mark.parametrize("transport", ["inproc", "socket"])
def test_process_agent_sigterm_identical_over_both_transports(transport):
    """A SIGTERM'd process node must produce the same observable story
    over sockets as over queues: lease-expiry (or EOF) detection, shard
    failover, every result exactly once."""
    be = DistributedBackend(n_nodes=2, node_mode="process",
                            transport=transport, heartbeat_timeout_s=1.0)
    try:
        # retry to steady state: a freshly spawned child's heartbeats can
        # gap while jax initializes under load, making it flap suspect —
        # one-node placement then is CORRECT behaviour, but this test
        # wants the steady state where both nodes share the wave
        inputs = np.random.default_rng(3).standard_normal((12, 8)).astype(
            np.float32)
        deadline = time.perf_counter() + 30.0
        while True:
            out, rec = be.launch(app, inputs, 12)
            np.testing.assert_allclose(np.asarray(out),
                                       inputs.sum(-1) * 3.0,
                                       rtol=1e-5, atol=1e-4)
            if rec.n_nodes == 2 or time.perf_counter() > deadline:
                break
            time.sleep(0.2)
        assert rec.n_nodes == 2
        be.agents["node1"].kill()           # hard process death
        out, rec = be.launch(app, inputs, 12)
        np.testing.assert_allclose(np.asarray(out), inputs.sum(-1) * 3.0,
                                   rtol=1e-5, atol=1e-4)
        # the wave was placed before detection: the dead shard moved
        assert rec.extra.get("failover") or rec.n_nodes == 1
    finally:
        be.close()


# ----------------------------------------------------------------------
# node-side staging
# ----------------------------------------------------------------------

def test_stager_attributes_overlap_to_the_busy_clock():
    """Staging seconds that elapse while the worker's busy clock advances
    are hidden; inline staging (the unoverlapped path) hides nothing."""
    busy = {"t": 0.0}
    stager = Stager(busy_clock=lambda: busy["t"])

    class _Advancing:
        """Array whose copy advances the fake busy clock (the worker
        'executes' while we stage)."""
        def __init__(self, arr):
            self._arr = arr
            self.dtype = arr.dtype
            self.size = arr.size

        def __array__(self, dtype=None, copy=None):
            busy["t"] += 0.004
            return np.array(self._arr, dtype=dtype)

    info = stager.stage("t1", {"x": _Advancing(np.ones(4))})
    assert info["hidden_s"] > 0.0
    assert info["hidden_s"] <= info["t_stage"] + 1e-9
    chunk, info2 = stager.take("t1")
    assert info2 is info
    np.testing.assert_array_equal(chunk["x"], np.ones(4))
    with pytest.raises(KeyError):
        stager.take("t1")                   # consumed exactly once
    _, inline = stager.stage_inline({"x": np.ones(4)})
    assert inline["hidden_s"] == 0.0 and not inline["overlapped"]
    assert stager.stats["shards"] == 2
