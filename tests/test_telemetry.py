"""Telemetry edge cases: the stage_rollup cache-hit aggregation fix
(sum each node's LATEST cumulative snapshot, never max() across waves),
zero-cost records keeping CSV rows parseable, and the rollup/summary
helpers on empty, all-superseded, and detail-free reports."""
from repro.core.telemetry import (HEADER, LaunchRecord, RequestRecord,
                                  class_summary, nodes_rollup,
                                  slo_attainment, stage_rollup, table)


def _wave(wave, node_caches, hits, misses):
    """One distributed wave record: per-node CUMULATIVE cache snapshots
    in node_records plus the wave-level dedup sum (the old code's only
    input)."""
    r = LaunchRecord("dist", n_instances=8)
    r.extra["stage"] = {
        "wall_s": 0.2, "hidden_s": 0.1,
        "bytes_on_wire": 100, "bytes_delivered": 400,
        "dedup": {"cache_hits": hits, "cache_misses": misses},
    }
    r.extra["node_records"] = [
        {"node": nid, "n": 4, "lo": 0, "hi": 4, "t_wave": 0.1,
         "stage_dedup": {"node_cache": dict(cache)}}
        for nid, cache in node_caches.items()]
    r.extra["wave"] = wave
    return r


def test_stage_rollup_sums_each_nodes_latest_snapshot():
    """Two nodes with UNEQUAL hit rates: node a ends at 9/1, node b at
    1/9. The report truth is 10 hits / 10 misses = 0.5 — the old
    max()-over-waves of per-wave sums cannot produce it (it conflates
    counters from different nodes and different instants)."""
    records = [
        _wave(0, {"a": {"hits": 4, "misses": 1},
                  "b": {"hits": 0, "misses": 5}}, hits=4, misses=6),
        _wave(1, {"a": {"hits": 9, "misses": 1},
                  "b": {"hits": 1, "misses": 9}}, hits=10, misses=10),
    ]
    out = stage_rollup(records)
    assert out["cache_hit_rate"] == 10 / 20
    # staging wall/bytes still sum across waves
    assert out["wall_s"] == 0.4
    assert out["bytes_on_wire"] == 200


def test_stage_rollup_node_leaving_fleet_keeps_its_last_counters():
    """A node that served wave 0 then left: its final snapshot still
    counts. Waves after its departure must not erase it (the old max()
    of per-wave sums silently could, when the survivor's sum was
    smaller)."""
    records = [
        _wave(0, {"a": {"hits": 8, "misses": 2},
                  "b": {"hits": 1, "misses": 1}}, hits=9, misses=3),
        _wave(1, {"b": {"hits": 2, "misses": 2}}, hits=2, misses=2),
    ]
    out = stage_rollup(records)
    # a's last snapshot (8/2) + b's last (2/2) = 10 hits / 4 misses
    assert out["cache_hit_rate"] == 10 / 14


def test_stage_rollup_falls_back_to_wave_dedup_without_node_detail():
    r = LaunchRecord("dist", n_instances=4)
    r.extra["stage"] = {"wall_s": 0.1, "hidden_s": 0.0,
                        "dedup": {"cache_hits": 3, "cache_misses": 1}}
    out = stage_rollup([r])
    assert out["cache_hit_rate"] == 0.75


def test_stage_rollup_without_dedup_has_no_hit_rate():
    r = LaunchRecord("dist", n_instances=4)
    r.extra["stage"] = {"wall_s": 0.1, "hidden_s": 0.05}
    out = stage_rollup([r])
    assert "cache_hit_rate" not in out
    assert out["hidden_frac"] == 0.5


def test_zero_cost_record_rate_is_zero_and_row_parseable():
    r = LaunchRecord("serial", n_instances=0)
    assert r.rate == 0.0
    row = r.row()
    assert "inf" not in row
    cols = row.split(",")
    assert len(cols) == len(HEADER.split(","))
    float(cols[7])                        # rate column parses as float
    # the full table round-trips through a naive CSV reader
    for line in table([r]).splitlines()[1:]:
        [float(c) for c in line.split(",")[1:]]


# ----------------------------------------------------------------------
# rollups and summaries on degenerate reports
# ----------------------------------------------------------------------

def test_rollups_on_empty_report():
    assert nodes_rollup([]) == {}
    out = stage_rollup([])
    assert out["wall_s"] == 0.0 and out["hidden_frac"] == 0.0
    assert "cache_hit_rate" not in out
    assert class_summary([]) == {}
    assert slo_attainment([], 0.5) == 1.0  # vacuously met


def test_rollups_on_all_superseded_report():
    """Every attempt lost a re-dispatch race: rollups still read their
    cost, instance counting excludes them."""
    rs = []
    for i in range(3):
        r = LaunchRecord("dist", n_instances=4, t_spawn=0.1)
        r.extra["superseded_by_redispatch"] = True
        r.extra["node_records"] = [{"node": "a", "n": 4, "t_wave": 0.1}]
        rs.append(r)
    roll = nodes_rollup(rs)
    assert roll["a"]["waves"] == 3
    assert roll["a"]["instances"] == 12
    assert all(r.superseded for r in rs)


def test_rollups_tolerate_records_missing_optional_extra():
    """Single-host records carry no node_records/stage/fanout at all."""
    r = LaunchRecord("array", n_instances=16, t_spawn=0.2)
    assert r.nodes() == {}
    assert r.n_nodes == 1
    assert not r.node_failure
    assert nodes_rollup([r]) == {}
    assert stage_rollup([r])["wall_s"] == 0.0
    # node_records entries may themselves omit optional keys
    r2 = LaunchRecord("dist", n_instances=4)
    r2.extra["node_records"] = [{"node": "a"}]     # bare minimum
    roll = nodes_rollup([r, r2])
    assert roll["a"]["instances"] == 0
    assert roll["a"]["t_stage"] == 0.0
    assert stage_rollup([r2])["wall_s"] == 0.0     # no crash, no dedup
    assert "cache_hit_rate" not in stage_rollup([r2])


def test_class_summary_with_unserved_requests():
    recs = [
        RequestRecord(rid=0, priority="interactive", ttft_s=0.1,
                      tpot_s=0.01, n_tokens=8),
        RequestRecord(rid=1, priority="interactive", ttft_s=0.0,
                      tpot_s=0.0, n_tokens=0,
                      finish="rejected_over_capacity"),
        RequestRecord(rid=2, priority="batch", ttft_s=0.9, tpot_s=0.02,
                      n_tokens=4, preemptions=2),
    ]
    cs = class_summary(recs)
    assert cs["interactive"]["n"] == 2            # rejected still counted
    assert cs["interactive"]["p50_ttft_s"] == 0.1  # but not averaged in
    assert cs["batch"]["preemptions"] == 2
    assert slo_attainment(recs, 0.5) == 0.5       # 1 of 2 SERVED met it
