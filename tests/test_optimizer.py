"""Optimizer unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   global_norm, schedule)


def test_adamw_matches_reference_numpy():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9, warmup_steps=0, total_steps=10**9)
    p = {"w_up": jnp.array([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w_up": jnp.array([0.1, 0.2, -0.3], jnp.float32)}
    st_ = adamw_init(p)
    p1, st1, _ = adamw_update(cfg, p, g, st_)
    # numpy reference
    m = 0.1 * np.asarray(g["w_up"])
    v = 0.001 * np.asarray(g["w_up"]) ** 2
    mh, vh = m / (1 - 0.9), v / (1 - 0.999)
    ref = np.asarray(p["w_up"]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w_up"]), ref, rtol=1e-5)
    assert int(st1["step"]) == 1


def test_grad_clip_applied():
    cfg = AdamWConfig(lr=1e-2, grad_clip=0.5, warmup_steps=0)
    p = {"w_up": jnp.ones((4,), jnp.float32)}
    g = {"w_up": jnp.full((4,), 100.0, jnp.float32)}
    _, _, m = adamw_update(cfg, p, g, adamw_init(p))
    assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


def test_decay_mask_skips_norms():
    cfg = AdamWConfig(lr=1.0, b1=0.0, b2=0.0, eps=1.0, weight_decay=0.5,
                      grad_clip=1e9, warmup_steps=0)
    p = {"w_up": jnp.ones((2,)), "norm_attn": {"scale": jnp.ones((2,))}}
    g = jax.tree_util.tree_map(jnp.zeros_like, p)
    p1, _, _ = adamw_update(cfg, p, g, adamw_init(p))
    assert float(p1["w_up"][0]) < 1.0               # decayed
    assert float(p1["norm_attn"]["scale"][0]) == 1.0  # not decayed


@given(step=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_schedule_bounded(step):
    cfg = AdamWConfig(lr=3e-4, warmup_steps=100, total_steps=10_000)
    lr = float(schedule(cfg, jnp.asarray(step)))
    assert 0.0 <= lr <= cfg.lr * 1.0001


def test_schedule_warmup_then_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=100, total_steps=1000,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(50))) == pytest.approx(5e-4)
    assert float(schedule(cfg, jnp.asarray(100))) == pytest.approx(1e-3)
    assert float(schedule(cfg, jnp.asarray(1000))) == pytest.approx(1e-4)


@given(vals=st.lists(st.floats(-10, 10, allow_nan=False), min_size=1,
                     max_size=8))
@settings(max_examples=30, deadline=None)
def test_global_norm_property(vals):
    t = {"a": jnp.asarray(vals, jnp.float32)}
    expect = np.linalg.norm(np.asarray(vals, np.float32))
    assert float(global_norm(t)) == pytest.approx(float(expect), abs=1e-4)


def test_moe_aux_loss_balancing_signal():
    """Uniform routing -> aux == 1 (its minimum); skewed routing -> > 1."""
    from repro.models.moe import moe_apply, moe_init
    from repro.models.spec import MoeSpec
    from repro.configs import get_config
    cfg = get_config("olmoe-1b-7b", smoke=True)
    spec = cfg.groups[0].pattern[0].moe
    params = moe_init(jax.random.PRNGKey(0), cfg.d_model, spec, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model),
                          jnp.bfloat16)
    _, aux = moe_apply(params, x, spec)
    assert float(aux) / spec.router_aux_weight >= 0.99
