"""CompileCache disk-tier policy: LRU-by-bytes eviction, jax-version
stamping, and the env-var budget knob."""
import os
import time

import numpy as np
import pytest

from repro.core.compile_cache import CompileCache, _version_tag


def _fn(salt):
    """A distinct tiny program per salt (closure const changes the key)."""
    def f(x, _s=salt):
        return x * _s + _s
    return f


X = (np.ones((4,), np.float32),)


def _aotx_files(d):
    return sorted(f for f in os.listdir(d) if f.endswith(".aotx"))


def _entry_size(tmp_path):
    d = str(tmp_path / "probe")
    c = CompileCache(cache_dir=d)
    c.compile(_fn(0), X, extras=("probe",))
    files = _aotx_files(d)
    assert files, "spill did not happen; cannot size an entry"
    return os.path.getsize(os.path.join(d, files[0]))


def test_lru_eviction_by_bytes(tmp_path):
    size = _entry_size(tmp_path)
    d = str(tmp_path / "aot")
    cache = CompileCache(cache_dir=d, max_bytes=int(size * 1.5))
    cache.compile(_fn(1), X, extras=("a",))
    time.sleep(0.05)                       # distinct mtimes for LRU order
    cache.compile(_fn(2), X, extras=("b",))
    # two entries > budget: the older one must have been evicted
    assert cache.stats["evictions"] >= 1
    assert len(_aotx_files(d)) == 1
    fresh = CompileCache(cache_dir=d)      # new process, same dir
    _, src_a = fresh.compile(_fn(1), X, extras=("a",))
    _, src_b = fresh.compile(_fn(2), X, extras=("b",))
    assert src_a == "compiled"             # evicted: cold again
    assert src_b == "disk"                 # survivor: warm across processes


def test_lru_recency_refreshed_by_disk_hit(tmp_path):
    size = _entry_size(tmp_path)
    d = str(tmp_path / "aot")
    warm = CompileCache(cache_dir=d)       # unbounded writer
    warm.compile(_fn(1), X, extras=("a",))
    time.sleep(0.05)
    warm.compile(_fn(2), X, extras=("b",))
    time.sleep(0.05)
    # a disk hit on A refreshes its mtime past B's
    reader = CompileCache(cache_dir=d, max_bytes=int(size * 2.5))
    _, src = reader.compile(_fn(1), X, extras=("a",))
    assert src == "disk"
    time.sleep(0.05)
    reader.compile(_fn(3), X, extras=("c",))   # spill -> prune over budget
    assert reader.stats["evictions"] >= 1
    check = CompileCache(cache_dir=d)
    _, src_a = check.compile(_fn(1), X, extras=("a",))
    _, src_b = check.compile(_fn(2), X, extras=("b",))
    assert src_a == "disk"                 # recently used: kept
    assert src_b == "compiled"             # least recently used: evicted


def test_alien_version_spills_are_dropped(tmp_path):
    d = str(tmp_path / "aot")
    os.makedirs(d)
    stale = os.path.join(d, "0" * 64 + ".deadbeef.aotx")
    with open(stale, "wb") as f:
        f.write(b"serialized-by-another-jax")
    cache = CompileCache(cache_dir=d)
    cache.compile(_fn(1), X, extras=("a",))
    assert not os.path.exists(stale)
    assert cache.stats["version_drops"] == 1
    # current-version spills carry the version tag in their name
    assert all(f.endswith(f".{_version_tag()}.aotx")
               for f in _aotx_files(d))


def test_max_bytes_env_knob(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_COMPILE_CACHE_MAX_BYTES", "12345")
    cache = CompileCache(cache_dir=str(tmp_path / "aot"))
    assert cache.max_bytes == 12345
    monkeypatch.delenv("REPRO_COMPILE_CACHE_MAX_BYTES")
    assert CompileCache(cache_dir=str(tmp_path / "aot2")).max_bytes is None
    # explicit argument wins over the env default
    monkeypatch.setenv("REPRO_COMPILE_CACHE_MAX_BYTES", "12345")
    assert CompileCache(cache_dir=str(tmp_path / "aot3"),
                        max_bytes=77).max_bytes == 77


def test_unbounded_cache_never_evicts(tmp_path):
    d = str(tmp_path / "aot")
    cache = CompileCache(cache_dir=d)
    for s in range(3):
        cache.compile(_fn(s + 10), X, extras=("u", s))
    assert cache.stats["evictions"] == 0
    assert len(_aotx_files(d)) == 3


@pytest.mark.parametrize("persistent", [True, False])
def test_memory_tier_unaffected_by_budget(tmp_path, persistent):
    """Eviction is a DISK policy: the in-memory tier still hits."""
    cache = CompileCache(cache_dir=str(tmp_path / "aot"),
                         persistent=persistent, max_bytes=1)
    cache.compile(_fn(1), X, extras=("m",))
    _, src = cache.compile(_fn(1), X, extras=("m",))
    assert src == "memory"
