"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs (the brief's required smoke gate)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.lm import cache_init, count_params, decode_step, lm_init, lm_loss, prefill
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_state, make_train_step

B, S = 2, 32


def make_batch(cfg, key=0):
    k = jax.random.PRNGKey(key)
    s_text = S
    batch = {}
    if cfg.frontend == "vlm_patch":
        s_text = S - cfg.frontend_len
        batch["embeds"] = 0.02 * jax.random.normal(
            k, (B, cfg.frontend_len, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "audio_frames":
        batch["frames"] = 0.02 * jax.random.normal(
            k, (B, cfg.encoder.seq_len, cfg.d_model), jnp.bfloat16)
    batch["tokens"] = jax.random.randint(k, (B, s_text), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(k, (B, s_text), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_no_nan(arch):
    cfg = get_config(arch, smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    loss, metrics = jax.jit(lambda p, b: lm_loss(p, b, cfg))(
        params, make_batch(cfg))
    assert jnp.isfinite(loss), (arch, loss)
    assert metrics["tokens"] > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_improves(arch):
    cfg = get_config(arch, smoke=True)
    step = make_train_step(cfg, AdamWConfig(lr=5e-3, warmup_steps=0),
                           remat=True)
    state = init_state(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    jstep = jax.jit(step)
    state, m0 = jstep(state, batch)
    for _ in range(4):
        state, m = jstep(state, batch)
    assert jnp.isfinite(m["loss"])
    assert float(m["loss"]) < float(m0["loss"]), (arch, m0["loss"], m["loss"])


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_shapes(arch):
    cfg = get_config(arch, smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    caches = cache_init(cfg, B, 64)
    enc = None
    if cfg.encoder is not None:
        enc = 0.02 * jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.encoder.seq_len, cfg.d_model),
            jnp.bfloat16)
        from repro.models.lm import encoder_apply
        enc = encoder_apply(params, enc, cfg)
    toks = jnp.ones((B, 1), jnp.int32)
    pos = jnp.zeros((B, 1), jnp.int32)
    logits, caches2 = jax.jit(
        lambda p, c, t, po: decode_step(p, c, t, po, cfg, enc_out=enc)
    )(params, caches, toks, pos)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch
    assert jax.tree_util.tree_structure(caches) == \
        jax.tree_util.tree_structure(caches2)


def test_param_counts_full_configs():
    """Full configs match published sizes (within naming-convention slack)."""
    expect = {
        "qwen3-14b": (13e9, 16e9),
        "gemma2-27b": (26e9, 29e9),
        "internvl2-76b": (69e9, 72e9),       # backbone == llama3-70b class
        "deepseek-v2-236b": (220e9, 250e9),
        "mamba2-1.3b": (1.1e9, 1.6e9),
        "olmoe-1b-7b": (6.3e9, 7.5e9),
        "zamba2-7b": (6.0e9, 8.5e9),
        "stablelm-12b": (11e9, 13.5e9),
        "gemma3-12b": (10e9, 13.5e9),
        "whisper-base": (5e7, 1.2e8),
    }
    for arch, (lo, hi) in expect.items():
        n = count_params(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("deepseek-v2-236b")
    total = count_params(cfg)
    active = count_params(cfg, active_only=True)
    assert active < 0.2 * total      # 21B active / 236B total class


def test_prefill_then_decode_runs():
    cfg = get_config("gemma3-12b", smoke=True)
    params = lm_init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, 16), 0, cfg.vocab)
    logits, caches = prefill(params, {"tokens": toks}, cfg, capacity=32)
    assert logits.shape == (B, 1, cfg.vocab)
    logits, _ = decode_step(params, caches, jnp.ones((B, 1), jnp.int32),
                            jnp.full((B, 1), 16, jnp.int32), cfg)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
