import os
import sys
import tempfile

# keep tests single-device (the dry-run sets its own 512-device flag in its
# own process); cap compilation parallelism for container stability
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

# tests that fall through to the default CompileCache must not spill AOT
# executables into (or warm-start from) the user's real ~/.cache dir
os.environ["REPRO_COMPILE_CACHE_DIR"] = tempfile.mkdtemp(
    prefix="repro-aot-test-")

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")

# the hermetic container has no `hypothesis`; fall back to the bundled
# deterministic stub so the property tests still collect and sweep
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro._compat.hypothesis_stub import build_module

    _hyp = build_module()
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _hyp.strategies
