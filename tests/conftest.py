import os

# keep tests single-device (the dry-run sets its own 512-device flag in its
# own process); cap compilation parallelism for container stability
os.environ.setdefault("XLA_FLAGS", "--xla_cpu_multi_thread_eigen=false")

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
