"""WaveController ``devices>1`` lane autoscaling on a REAL multi-device
mesh. CPU CI has one device, so this module runs the scenario in a
subprocess with ``--xla_force_host_platform_device_count=8`` (the flag
must be set before jax initializes — it cannot be applied in-process
once conftest has imported jax)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import tempfile

import jax
import numpy as np

assert jax.device_count() == 8, f"expected 8 fake devices, got {jax.device_count()}"

from repro.core.autoscale import WaveController
from repro.core.backend import PipelinedBackend
from repro.core.compile_cache import CompileCache
from repro.core.llmr import LLMapReduce


def app(x):
    return (x * 2.0).sum(axis=-1)


# controller policy on the real device count: hierarchy, exact reshape
c = WaveController(n_tasks=4096, devices=len(jax.devices()), start_wave=512)
d = c.next_wave(4096)
assert d.inner_lanes > 1, d
assert d.wave % d.inner_lanes == 0, d
assert d.wave // d.inner_lanes >= 8, d

# end to end: auto-sized waves over a real 8-way mesh must produce
# hierarchical (core > 1) fan-outs AND the right numbers
mesh = jax.make_mesh((8,), ("data",))
be = PipelinedBackend(mesh=mesh,
                      cache=CompileCache(cache_dir=tempfile.mkdtemp()))
inputs = np.random.default_rng(0).standard_normal((512, 8)).astype(np.float32)
llmr = LLMapReduce(mesh=mesh, wave_size="auto", backend=be)
out, rep = llmr.map_reduce(app, inputs)
np.testing.assert_allclose(np.asarray(out), inputs.sum(-1) * 2.0,
                           rtol=1e-4, atol=1e-4)
assert rep.n_instances == 512
hier = [r for r in rep.records if r.fanout.get("core", 1) > 1]
assert hier, [r.fanout for r in rep.records]
lanes = [d.inner_lanes for d in rep.autoscale]
assert max(lanes) > 1, lanes
print(f"MULTIDEVICE_OK waves={rep.waves} "
      f"max_core={max(r.fanout.get('core', 1) for r in rep.records)}")
"""


def test_lane_autoscaling_on_eight_fake_devices():
    env = dict(os.environ)
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                        "--xla_cpu_multi_thread_eigen=false")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = (os.path.join(ROOT, "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr}\nstdout:\n{proc.stdout}"
    assert "MULTIDEVICE_OK" in proc.stdout, proc.stdout
