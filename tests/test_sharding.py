"""Partition-rule resolution properties (divisibility, priority, fallback),
with hypothesis over shapes."""
import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.sharding.partition import (ACT_RULES, PARAM_RULES, cache_sharding,
                                      param_sharding, resolve_spec)


def mesh2(data=4, model=2):
    # build a logical mesh over repeated devices is not allowed; use a
    # small abstract mesh via AbstractMesh for spec resolution tests.
    # AbstractMesh's signature changed across jax versions: 0.4.x takes
    # ((name, size), ...), newer takes (sizes, names).
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((("data", data), ("model", model)))
    except TypeError:
        return AbstractMesh((data, model), ("data", "model"))


def test_divisible_dims_get_sharded():
    m = mesh2()
    spec = resolve_spec((8, 16), ("d_model", "ff"), m, PARAM_RULES)
    assert spec == P("data", "model")


def test_indivisible_dim_falls_back_to_replication():
    m = mesh2()
    spec = resolve_spec((8, 15), ("d_model", "ff"), m, PARAM_RULES)
    assert spec == P("data", None)


def test_heads_fallback_to_seq():
    """qwen3 pattern: heads not divisible -> seq takes the model axis."""
    m = mesh2()
    spec = resolve_spec((8, 64, 9, 16), ("batch", "seq", "heads", "head_dim"),
                        m, ACT_RULES["train"])
    assert spec[2] is None and spec[1] == "model"


def test_heads_win_over_seq_when_divisible():
    m = mesh2()
    spec = resolve_spec((8, 64, 8, 16), ("batch", "seq", "heads", "head_dim"),
                        m, ACT_RULES["train"])
    assert spec[2] == "model" and spec[1] is None


def test_cache_batch1_falls_back_to_seq_sharding():
    m = mesh2()
    # long_500k: batch=1 cannot shard -> kv_heads takes `model` (divisible on
    # this small mesh) and cache_seq picks up `data`
    spec = resolve_spec((1, 1024, 8, 64),
                        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                        m, ACT_RULES["serve"])
    assert spec[0] is None
    assert spec[1] == "data" and spec[2] == "model"
    # with kv_heads indivisible (the 16-way production case) cache_seq takes both
    spec = resolve_spec((1, 1024, 3, 64),
                        ("cache_batch", "cache_seq", "kv_heads", "head_dim"),
                        m, ACT_RULES["serve"])
    assert spec[1] == ("data", "model") and spec[2] is None


@given(d0=st.integers(1, 64), d1=st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_resolution_always_divides(d0, d1):
    m = mesh2()
    spec = resolve_spec((d0, d1), ("d_model", "ff"), m, PARAM_RULES)
    mesh_shape = dict(zip(("data", "model"), (4, 2)))
    for dim, part in zip((d0, d1), spec):
        if part is None:
            continue
        axes = part if isinstance(part, tuple) else (part,)
        k = int(np.prod([mesh_shape[a] for a in axes]))
        assert dim % k == 0


def test_no_axis_reused_within_tensor():
    m = mesh2()
    spec = resolve_spec((8, 8, 8), ("experts", "d_model", "ff"), m,
                        PARAM_RULES)
    used = []
    for part in spec:
        if part is None:
            continue
        used += list(part) if isinstance(part, tuple) else [part]
    assert len(used) == len(set(used))


def test_param_tree_sharding_covers_all_archs():
    from repro.configs import ARCHS, get_config
    from repro.models.lm import lm_init
    m = mesh2()
    for arch in ARCHS:
        cfg = get_config(arch, smoke=True)
        shapes = jax.eval_shape(lambda c=cfg: lm_init(jax.random.PRNGKey(0), c))
        tree = param_sharding(shapes, m)
        n = len(jax.tree_util.tree_leaves(
            tree, is_leaf=lambda x: hasattr(x, "spec")))
        assert n == len(jax.tree_util.tree_leaves(shapes))
