"""int8 KV cache: decode matches the bf16 full-forward within quant noise."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.common import dense_lm
from repro.models.lm import decode_step, lm_hidden, lm_init, lm_logits, prefill


def _quantized(cfg):
    groups = []
    for g in cfg.groups:
        pat = []
        for b in g.pattern:
            if b.attn is not None:
                b = dataclasses.replace(
                    b, attn=dataclasses.replace(b.attn, kv_quant=True))
            pat.append(b)
        groups.append(dataclasses.replace(g, pattern=tuple(pat)))
    return dataclasses.replace(cfg, groups=tuple(groups))


def test_int8_kv_decode_close_to_fp():
    cfg = _quantized(dense_lm("kvq", n_layers=2, d_model=64, n_heads=4,
                              n_kv=2, head_dim=16, d_ff=128, vocab=256))
    params = lm_init(jax.random.PRNGKey(0), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    h, _, _ = lm_hidden(params, {"tokens": toks}, cfg)
    full = lm_logits(params, h, cfg).astype(jnp.float32)

    sp = S - 4
    lg, caches = prefill(params, {"tokens": toks[:, :sp]}, cfg, capacity=S)
    assert caches[0]["0"]["attn"]["k"].dtype == jnp.int8
    outs = [lg]
    for i in range(sp, S):
        lg, caches = decode_step(params, caches, toks[:, i:i + 1],
                                 jnp.full((B, 1), i, jnp.int32), cfg)
        outs.append(lg)
    dec = jnp.concatenate(outs[:-1], axis=1).astype(jnp.float32)
    ref = full[:, sp - 1:S - 1]
    err = float(jnp.abs(dec - ref).max() / (jnp.abs(ref).max() + 1e-9))
    # int8 kv noise budget: well under 8% relative on logits
    assert err < 0.08, f"int8 kv decode err {err:.3e}"


def test_quantize_roundtrip_bounds():
    from repro.models.attention import _kv_dequantize, _kv_quantize
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 4, 16), jnp.bfloat16)
    q, s = _kv_quantize(x)
    y = _kv_dequantize(q, s)
    err = np.abs(np.asarray(y, np.float32) - np.asarray(x, np.float32))
    # half-step quant error + bf16 rounding of the scale and the product
    bound = (np.asarray(s, np.float32)[..., None] * 0.51
             + 0.01 * np.abs(np.asarray(x, np.float32)) + 1e-3)
    assert (err <= bound).all()
